"""Cross-plan checkpoint resharding (ROADMAP "Elastic re-planning"):
the stage re-slicing machinery must be a bit-exact bijection between
pipeline layouts, and a checkpoint restored onto a different
(technique x placement x stage_layers) layout must carry every leaf —
params AND AdamW moments — unchanged.

Host-side tests run the canonical <-> staged-view mappers directly
(``repro.train.reshard``); the slow tests drive the full train →
checkpoint → reshard → resume path through ``repro.launch
.reshard_check`` subprocesses (forced host device counts lock at first
jax init).  The (stage, 1, 1) pipeline meshes there are fully manual,
so everything runs even on jax 0.4.x (repro.compat.NATIVE_SHARD_MAP).
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from prophelpers import given, settings, st
from repro.core.pipeline import stage_gather_index
from repro.core.plans import Placement
from repro.train.reshard import (normalized_stage_layers, restage,
                                 stage_view, unstage_view)


def _stack(n_layers, extra_shape=(3,), seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((n_layers,) + extra_shape).astype(
            np.float32),
        "b": rng.standard_normal((n_layers, 2)).astype(np.float32),
    }


# ------------------------------------------------------------------ #
# stage view mechanics (host, fast)
# ------------------------------------------------------------------ #

def test_stage_view_matches_runtime_gather_index():
    """stage_view applies exactly the trace-time gather convention."""
    split, n_stages = (3, 1), 2
    stack = _stack(4)
    staged, valid = stage_view(stack, split, n_stages)
    idx, valid_ref = stage_gather_index(split, n_stages)
    np.testing.assert_array_equal(valid, valid_ref)
    np.testing.assert_array_equal(staged["w"],
                                  np.take(stack["w"], idx, axis=0))


def test_stage_view_pads_by_repeating_last_layer():
    stack = _stack(3)
    staged, valid = stage_view(stack, (2, 1), 2)
    assert staged["w"].shape[0] == 4            # 2 stages x max(2, 1)
    # stage 1's padding slot repeats its last (only) real layer
    np.testing.assert_array_equal(staged["w"][3], stack["w"][2])
    np.testing.assert_array_equal(valid, [True, True, True, False])


@pytest.mark.parametrize("split,n_stages,schedule", [
    ((2, 2), 2, "gpipe"),
    ((3, 1), 2, "gpipe"),
    ((3, 3, 1), 3, "gpipe"),
    ((5, 2, 2), 3, "1f1b"),
    ((1, 1, 2, 2), 2, "interleaved"),           # virt=2: 4 chunks
])
def test_unstage_inverts_stage_view(split, n_stages, schedule):
    stack = _stack(sum(split))
    staged, _ = stage_view(stack, split, n_stages, schedule=schedule)
    back = unstage_view(staged, split, n_stages, schedule=schedule)
    for k in stack:
        np.testing.assert_array_equal(back[k], stack[k])


def test_restage_across_stage_counts_and_orders():
    """2-stage even -> 3-stage uneven (7 layers) equals staging the
    canonical stack directly; a reversal is just another restage."""
    stack = _stack(7)
    src, _ = stage_view(stack, (4, 3), 2)
    dst, valid = restage(src, (4, 3), 2, (3, 3, 1), 3)
    ref, valid_ref = stage_view(stack, (3, 3, 1), 3)
    for k in stack:
        np.testing.assert_array_equal(dst[k], ref[k])
    np.testing.assert_array_equal(valid, valid_ref)
    # round-trip back to the 2-stage layout is the identity
    back, _ = restage(dst, (3, 3, 1), 3, (4, 3), 2)
    for k in stack:
        np.testing.assert_array_equal(back[k], src[k])


def test_unstage_rejects_wrong_leading_axis():
    staged, _ = stage_view(_stack(4), (2, 2), 2)
    with pytest.raises(ValueError, match="leading axis"):
        unstage_view(staged, (3, 3), 2)
    with pytest.raises(ValueError, match="entries"):
        unstage_view(staged, (2, 2, 2), 2)


def test_normalized_stage_layers():
    assert normalized_stage_layers(6, Placement((0, 1))) == (3, 3)
    assert normalized_stage_layers(
        7, Placement((0, 1, 2), stage_layers=(3, 3, 1))) == (3, 3, 1)
    # interleaved doubles the chunk count
    assert normalized_stage_layers(
        8, Placement((0, 1), schedule="interleaved")) == (2, 2, 2, 2)
    with pytest.raises(ValueError, match="divide"):
        normalized_stage_layers(7, Placement((0, 1, 2)))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_stage_roundtrip_property(data):
    """Any split of any stack round-trips bit-exactly through the
    padded stage-major view, under any virtual-stage factor."""
    n_stages = data.draw(st.integers(1, 4), label="n_stages")
    virt = data.draw(st.integers(1, 2), label="virt")
    split = tuple(data.draw(
        st.lists(st.integers(1, 4), min_size=n_stages * virt,
                 max_size=n_stages * virt), label="split"))
    schedule = "gpipe" if virt == 1 else f"interleaved{virt}"
    stack = _stack(sum(split),
                   extra_shape=tuple(data.draw(
                       st.lists(st.integers(1, 3), max_size=2),
                       label="extra")),
                   seed=data.draw(st.integers(0, 99), label="seed"))
    staged, valid = stage_view(stack, split, n_stages, schedule=schedule)
    assert staged["w"].shape[0] == n_stages * virt * max(split)
    assert int(valid.sum()) == sum(split)
    back = unstage_view(staged, split, n_stages, schedule=schedule)
    for k in stack:
        np.testing.assert_array_equal(back[k], stack[k])


# ------------------------------------------------------------------ #
# full checkpoint reshard scenarios (subprocess, slow)
# ------------------------------------------------------------------ #

def _run_check(env, extra=(), timeout=560):
    cmd = [sys.executable, "-m", "repro.launch.reshard_check", *extra]
    out = subprocess.run(cmd, capture_output=True, text=True,
                         timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    return json.loads(line)


def _assert_bitexact_and_step_parity(res):
    assert res["params_bitexact"], res
    assert res["opt_bitexact"], res
    assert res["host_bitexact"], res
    assert res["max_param_diff"] == 0.0
    assert res["max_opt_diff"] == 0.0
    # one further step from the resharded state == the unresharded control
    assert res["loss_resharded"] == res["loss_control"]


@pytest.mark.slow
def test_reshard_zero2_two_sites_to_fsdp_one_site(subproc_env):
    """zero2@{V1,V2} -> fsdp@V1: ZeRO-partitioned moments re-place onto
    the fully-sharded single-site layout bit-exactly."""
    res = _run_check(subproc_env, (
        "--src-plan", "zero2", "--src-sites", "0,1",
        "--dst-plan", "fsdp", "--dst-sites", "0"))
    _assert_bitexact_and_step_parity(res)


@pytest.mark.slow
def test_reshard_data_to_three_stage_uneven_pipeline(subproc_env):
    """data@V1 -> pipeshard 3 stages over 7 layers (3,3,1): the
    destination's uneven pad-and-mask layout restores bit-exactly and
    trains on."""
    res = _run_check(subproc_env, (
        "--src-plan", "data", "--src-sites", "0",
        "--dst-plan", "pipeshard", "--dst-sites", "0,1,2",
        "--dst-layers", "3,3,1", "--layers", "7"))
    _assert_bitexact_and_step_parity(res)


@pytest.mark.slow
def test_reshard_pipeline_two_to_three_stages(subproc_env):
    """pipeshard 2 stages -> 3 stages: a stage-count change (the
    elastic join/leave case) maps straight through."""
    res = _run_check(subproc_env, (
        "--src-plan", "pipeshard", "--src-sites", "0,1",
        "--dst-plan", "pipeshard", "--dst-sites", "0,1,2",
        "--layers", "6"))
    _assert_bitexact_and_step_parity(res)


@pytest.mark.slow
def test_reshard_pipeline_stage_order_reversal(subproc_env):
    """Reversing the stage->site order changes only device placement,
    never values — and one further step is placement-invariant."""
    res = _run_check(subproc_env, (
        "--src-plan", "pipeshard", "--src-sites", "0,1",
        "--dst-plan", "pipeshard", "--dst-sites", "0,1",
        "--dst-order", "1,0", "--layers", "4"))
    _assert_bitexact_and_step_parity(res)
    # the source plan's own continuation agrees too (same math)
    assert res["loss_src_continue"] == res["loss_control"]


@pytest.mark.slow
def test_chaos_kill_site_replan_resume(subproc_env):
    """The pinned recovery gate: kill one site of a two-site Pipeshard
    run mid-epoch; the replan lands on the survivor, the resharded
    optimizer state is bit-exact vs the host-side reference, and the
    resumed loss sequence matches the single-site control exactly."""
    res = _run_check(subproc_env, (
        "--chaos", "--kill-step", "3", "--dead", "1",
        "--total-steps", "6", "--ckpt-every", "2"))
    assert res["failed"]
    assert res["technique"] in ("data", "zero2", "shard")
    assert res["sites_old"] == [0]              # the survivor, original id
    assert res["resumed_from"] == 2             # newest complete checkpoint
    assert res["steps_lost"] == 1               # killed at 3, resumed at 2
    assert res["params_bitexact"] and res["opt_bitexact"]
    assert res["losses_post"] == res["losses_control"]
    assert len(res["losses_pre"]) == 3          # steps 0..2 ran
    assert len(res["losses_post"]) == 4         # steps 2..5 re-ran/ran
    assert all(np.isfinite(res["losses_post"]))
