"""The identity-calibration differential gate (ISSUE 9): an empty
``Calibration`` overlay must leave every pinned selection of PRs 1-8
bit-for-bit unchanged — same winners, same ``==``-equal prices — and a
pinned non-identity overlay (an A30 cell achieving 60% of datasheet)
must flip a known cell, with the *fitted* overlay reproducing the
ground-truth-priced search exactly (docs/calibration.md §4)."""
import math

import numpy as np
import pytest

from repro.calib.fit import fit_calibration
from repro.calib.microbench import synthetic_measurements
from repro.calib.overlay import Calibration, LinkRate
from repro.configs import get_config
from repro.core.costmodel import (ALL_TECHNIQUES, PAPER_CLUSTERS,
                                  paper_workload, technique_step_cost)
from repro.core.search import PlanSearch
from repro.core.selector import CostModelProber, select_technique
from repro.core.topology import Link, Site, line, two_site

from benchmarks.paper_alg1 import PAPER_EXPECTED

WL_M = paper_workload(get_config("gpt2m"))
IDENT = Calibration.identity()
WIRE_POOL = ("fp32", "bf16", "int8")


def _sites(n, gpu="A30"):
    return [Site((gpu, gpu), name=f"S{i}") for i in range(n)]


def _ranked(search: PlanSearch):
    return [(s.candidate.key, s.tflops) for s in search.search()]


# ------------------------------------------------------------------ #
# identity leaves every pinned gate bit-for-bit
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("cname,mname", sorted(PAPER_EXPECTED))
def test_identity_keeps_table2_selections(cname, mname):
    """All 10 Algorithm-1 Table-II winners: probing through the identity
    overlay must reproduce the uncalibrated selection — technique, VM
    list, and every probe value ``==``-equal."""
    wl = paper_workload(get_config(mname))
    cluster = PAPER_CLUSTERS[cname]
    base = select_technique(CostModelProber(wl, cluster), delta=0.1)
    cal = select_technique(CostModelProber(wl, cluster, calibration=IDENT),
                           delta=0.1)
    assert (cal.technique, cal.vms) == (base.technique, base.vms)
    assert cal.probes == base.probes
    key = (base.technique, tuple(base.vms) if base.vms else None)
    assert key in PAPER_EXPECTED[(cname, mname)]


def test_identity_keeps_1f1b_memory_flip():
    """The PR-4 pinned gate: gpt2L@52 on a 3-site RTX line flips to
    Pipeshard under 1F1B's smaller stash.  The identity overlay must
    reproduce the flip and the full ranked list bit-for-bit."""
    wl = paper_workload(get_config("gpt2L"), global_batch=52)
    topo = line("rtx3", _sites(3, gpu="RTX"), [Link(57.4e-3, 3.0)] * 2)
    base = PlanSearch(wl, topo)
    cal = PlanSearch(wl, topo, calibration=IDENT)
    assert _ranked(base) == _ranked(cal)
    best = cal.best()
    assert (best.candidate.technique, best.candidate.schedule) == \
        ("pipeshard", "1f1b")


def test_identity_keeps_fsdp_and_shard_zero_gates():
    """The PR-5 pinned gates: fsdp rescues TACC-TACC gpt2L; shard_zero
    wins the T4 metro line — identical under the identity overlay."""
    wl = paper_workload(get_config("gpt2L"))
    c = PAPER_CLUSTERS["TACC-TACC"]
    base = PlanSearch.for_cluster(wl, c, techniques=ALL_TECHNIQUES)
    cal = PlanSearch.for_cluster(wl, c, techniques=ALL_TECHNIQUES,
                                 calibration=IDENT)
    assert _ranked(base) == _ranked(cal)
    assert cal.best().candidate.technique == "fsdp"

    topo = line("lan3", _sites(3, gpu="T4"), [Link(0.1e-3, 3.0)] * 2)
    base = PlanSearch(wl, topo, techniques=ALL_TECHNIQUES)
    cal = PlanSearch(wl, topo, techniques=ALL_TECHNIQUES,
                     calibration=IDENT)
    assert _ranked(base) == _ranked(cal)
    assert cal.best().candidate.technique == "shard_zero"


def test_identity_keeps_int8_wire_flip():
    """The PR-6 pinned gate: the regional A30 cell flips data ->
    pipeshard~int8 when the wire pool widens — identical rankings
    through the identity overlay."""
    topo = two_site("a30x2", ("A30", "A30"), ("A30", "A30"), 20.2)
    base = PlanSearch(WL_M, topo, wire_dtypes=WIRE_POOL)
    cal = PlanSearch(WL_M, topo, wire_dtypes=WIRE_POOL,
                     calibration=IDENT)
    assert _ranked(base) == _ranked(cal)
    assert cal.best().candidate.key == "pipeshard@V1+V2~int8"


# ------------------------------------------------------------------ #
# a pinned non-identity overlay flips a known cell
# ------------------------------------------------------------------ #

# the paper's regional two-A30-site cell (Table I RTT), whose winner at
# datasheet rates is single-site Data
FLIP_TOPO = two_site("a30x2", ("A30", "A30"), ("A30", "A30"), 20.2)
# a cluster whose A30s achieve 60% of datasheet (15 of 25 TFLOP/s) —
# measured comm rates unchanged, so compute's share of every step grows
# and the 4-GPU pipeline overtakes the 2-GPU single-site plan
SLOW_A30 = Calibration(site_tflops={0: 15.0, 1: 15.0},
                       note="A30s at 60% of datasheet")


def test_slow_a30_calibration_flips_regional_cell():
    """The pinned calibration flip (ISSUE 9): at datasheet rates the
    regional A30 cell picks data@V1; under the 60%-of-datasheet
    overlay the winner flips to pipeshard@V1+V2 — slower compute with
    unchanged links shifts the balance toward the plan that halves the
    per-GPU compute share."""
    base = PlanSearch(WL_M, FLIP_TOPO).best()
    assert base.candidate.key == "data@V1"
    slow = PlanSearch(WL_M, FLIP_TOPO, calibration=SLOW_A30).best()
    assert slow.candidate.key == "pipeshard@V1+V2"
    # sanity: the slow cluster is slower in absolute terms
    assert slow.tflops < base.tflops


def test_fitted_overlay_search_matches_ground_truth_search():
    """Close the loop: fit an overlay from zero-noise synthetic
    measurements generated by the slow-A30 ground truth (plus a
    measured WAN), then search under the *fitted* overlay — the ranked
    candidate keys must equal the ground-truth-priced search's and the
    flip must reproduce."""
    truth = Calibration(site_tflops={0: 15.0, 1: 15.0},
                        links={(0, 1): LinkRate(22e-3, 2.4)},
                        note="ground truth")
    rng = np.random.default_rng(7)
    samples = synthetic_measurements(
        FLIP_TOPO, truth, rng=rng, noise=0.0, wl=WL_M,
        step_placements=[("data", (0,), {}), ("zero2", (0, 1), {}),
                         ("pipeshard", (0, 1),
                          {"stage_order": (0, 1)})])
    fitted = fit_calibration(FLIP_TOPO, samples).calibration
    gt = PlanSearch(WL_M, FLIP_TOPO, calibration=truth).search()
    ft = PlanSearch(WL_M, FLIP_TOPO, calibration=fitted).search()
    assert [s.candidate.key for s in gt] == [s.candidate.key for s in ft]
    for g, f in zip(gt, ft):
        if g.tflops is None:
            assert f.tflops is None
        else:
            assert math.isclose(g.tflops, f.tflops, rel_tol=1e-9)


def test_calibrated_pruning_stays_lossless():
    """Dominance pruning reads rates through the overlay, so the pruned
    search must still equal the exhaustive one under a calibration that
    reverses which subset dominates (site 1's T4s measured faster than
    site 0's A30s)."""
    topo = two_site("mix", ("A30", "A30"), ("T4", "T4"), 20.2)
    cal = Calibration(site_tflops={0: 6.0, 1: 18.0},
                      links={(1, 1): LinkRate(1e-6, 30.0)})
    s = PlanSearch(WL_M, topo, techniques=ALL_TECHNIQUES,
                   calibration=cal)
    pruned = {(c.candidate.key, c.tflops) for c in s.search(prune=True)
              if c.feasible}
    exact = {(c.candidate.key, c.tflops) for c in s.search(prune=False)
             if c.feasible}
    best_p = max(pruned, key=lambda kv: kv[1])
    best_e = max(exact, key=lambda kv: kv[1])
    assert best_p == best_e
    assert pruned <= exact
