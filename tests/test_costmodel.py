"""Paper-validation tests: the cost model must reproduce the paper's
claims C1–C5 (orderings, latency degradation, OOM boundaries, Algorithm 1
selections) — these are the EXPERIMENTS.md §Paper-validation gates."""
import dataclasses
import itertools

import numpy as np
import pytest
from prophelpers import given, settings, st

from repro.configs import get_config
from repro.core.costmodel import (ALL_TECHNIQUES, GPUS, PAPER_CLUSTERS,
                                  SCHEDULES, TECHNIQUES, TECHNIQUE_SPECS,
                                  Cluster, Link, MemoryModel, TechniqueSpec,
                                  VM, avg_tflops, balanced_stage_layers,
                                  carrier_scale, epoch_minutes,
                                  fabric_cluster, paper_workload,
                                  parse_schedule,
                                  pipeline_bubble_fraction,
                                  pipeline_inflight_microbatches,
                                  register_technique,
                                  stage_compute_tflops,
                                  technique_state_bytes,
                                  technique_step_cost)
from repro.core.selector import CostModelProber, select_technique
from repro.core.topology import Site, line, make_topology, ring

WL_M = paper_workload(get_config("gpt2m"))
WL_L = paper_workload(get_config("gpt2L"))
MULTI_SITE = ["UTAH-GPN", "UTAH-MASS", "BRIS-STAR", "GAT-AMST"]


def test_c1_pipeshard_fastest_when_geo_distributed():
    for name in MULTI_SITE:
        c = PAPER_CLUSTERS[name]
        times = {t: epoch_minutes(t, WL_M, c)
                 for t in ("data", "zero2", "shard", "pipeshard")}
        ran = {k: v for k, v in times.items() if v is not None}
        assert min(ran, key=ran.get) == "pipeshard", (name, times)


def test_c2_shard_degrades_worst_with_latency():
    degr = {}
    for t in ("data", "zero2", "shard", "pipeshard"):
        t0 = epoch_minutes(t, WL_M, PAPER_CLUSTERS["TACC-TACC"])
        t4 = epoch_minutes(t, WL_M, PAPER_CLUSTERS["GAT-AMST"])
        degr[t] = t4 / t0
    assert degr["shard"] == max(degr.values())
    assert degr["pipeshard"] == min(degr.values())
    # paper magnitudes: pipeshard ~3.4x, shard ~66x
    assert degr["pipeshard"] < 5
    assert degr["shard"] > 20


def test_c3_single_vm_data_beats_pipeshard_on_fast_island():
    c = PAPER_CLUSTERS["TACC-TACC"]
    one_vm = avg_tflops("data", WL_M, c, vms=[0])
    four = avg_tflops("pipeshard", WL_M, c)
    assert one_vm > four  # paper: 15.74 vs 12.17 TFLOP/s


def test_c4_zero2_is_the_low_memory_fallback():
    """gpt2L on the T4-limited clusters: ZeRO2 fits where data/pipeshard
    don't (paper Figs 3-4)."""
    for name in ("TACC-TACC", "UTAH-GPN"):
        c = PAPER_CLUSTERS[name]
        fits = {t: technique_step_cost(t, WL_L, c).fits
                for t in ("data", "zero2", "pipeshard")}
        assert fits["zero2"], name
        assert not fits["data"], name
        assert not fits["pipeshard"], name


def test_c4b_pipeshard_fits_on_24gb_cluster():
    c = PAPER_CLUSTERS["UTAH-MASS"]  # 4x RTX 24GB
    assert technique_step_cost("pipeshard", WL_L, c).fits
    assert technique_step_cost("data", WL_L, c).fits


def test_c5_algorithm1_selections_match_paper():
    import benchmarks.paper_alg1 as alg
    assert alg.run(print_fn=lambda *_: None) == 0


def test_paper_benchmark_claims_pass():
    import benchmarks.paper_figs as figs
    import benchmarks.paper_table2 as t2
    assert figs.run(print_fn=lambda *_: None) == 0
    assert t2.run(print_fn=lambda *_: None) == 0


@settings(max_examples=25, deadline=None)
@given(
    lat1=st.floats(0.1, 50.0),
    lat2=st.floats(50.1, 150.0),
)
def test_latency_monotonicity_property(lat1, lat2):
    """More latency never speeds anything up, and pipeshard's degradation
    ratio is always <= data's (the paper's central finding)."""
    c1 = fabric_cluster("lo", ("RTX", "RTX"), ("RTX", "RTX"), lat1)
    c2 = fabric_cluster("hi", ("RTX", "RTX"), ("RTX", "RTX"), lat2)
    for tech in ("data", "zero2", "shard", "pipeshard"):
        t1 = technique_step_cost(tech, WL_M, c1).total_s
        t2_ = technique_step_cost(tech, WL_M, c2).total_s
        assert t2_ >= t1 * 0.999, tech
    deg = lambda t: technique_step_cost(t, WL_M, c2).total_s \
        / technique_step_cost(t, WL_M, c1).total_s
    assert deg("pipeshard") <= deg("data") * 1.001


@settings(max_examples=15, deadline=None)
@given(lat=st.floats(0.1, 150.0))
def test_selector_always_returns_feasible_or_none(lat):
    c = fabric_cluster("x", ("A30", "A30"), ("T4", "T4"), lat)
    sel = select_technique(CostModelProber(WL_M, c), delta=0.1)
    assert sel.technique in ("data", "zero2", "shard", "pipeshard", "none")
    if sel.technique != "none":
        assert sel.vms is not None


def test_heterogeneous_cluster_paced_by_slowest():
    """Data parallel with a T4 in the pool is slower than all-A30."""
    fast = fabric_cluster("f", ("A30", "A30"), ("A30", "A30"), 1.0)
    slow = fabric_cluster("s", ("A30", "A30"), ("T4", "T4"), 1.0)
    assert technique_step_cost("data", WL_M, slow).compute_s > \
        technique_step_cost("data", WL_M, fast).compute_s


# ------------------------------------------------------------------ #
# pipeline schedules (docs/schedules.md): bubble and memory terms
# ------------------------------------------------------------------ #

def test_parse_schedule():
    assert parse_schedule("gpipe") == ("gpipe", 1)
    assert parse_schedule("1f1b") == ("1f1b", 1)
    assert parse_schedule("interleaved") == ("interleaved", 2)
    assert parse_schedule("interleaved4") == ("interleaved", 4)
    for bad in ("INTERLEAVED", "interleaved1", "interleavedx", "1F1B"):
        with pytest.raises(ValueError):
            parse_schedule(bad)


@settings(max_examples=50, deadline=None)
@given(S=st.integers(1, 8), m=st.integers(1, 32), v=st.integers(2, 4))
def test_schedule_bubble_property(S, m, v):
    """1F1B's bubble equals GPipe's; the interleaved schedule divides it
    by v (strictly shallower whenever there is a bubble at all)."""
    gp = pipeline_bubble_fraction("gpipe", S, m)
    assert gp == (S - 1) / m
    assert pipeline_bubble_fraction("1f1b", S, m) == gp
    il = pipeline_bubble_fraction(f"interleaved{v}", S, m)
    assert il == pytest.approx(gp / v)
    if S > 1:
        assert il < gp


@settings(max_examples=50, deadline=None)
@given(S=st.integers(1, 8), m=st.integers(1, 32))
def test_schedule_memory_property(S, m):
    """1F1B never stashes more than GPipe (strictly less once m > S);
    interleaving costs a little above 1F1B; and every schedule's
    in-flight count is monotone non-decreasing in m."""
    gp = pipeline_inflight_microbatches("gpipe", S, m)
    f1b = pipeline_inflight_microbatches("1f1b", S, m)
    il = pipeline_inflight_microbatches("interleaved", S, m)
    assert gp == m
    assert f1b == min(S, m) <= gp
    if m > S:
        assert f1b < gp
    assert f1b <= il
    for sched in SCHEDULES:
        a = pipeline_inflight_microbatches(sched, S, m)
        b = pipeline_inflight_microbatches(sched, S, m + 1)
        assert b >= a, sched


def test_gpipe_schedule_is_the_legacy_cost_bit_for_bit():
    """schedule="gpipe" must keep every paper number: same bubble term,
    same m-in-flight memory, no p2p multiplier."""
    for name, c in PAPER_CLUSTERS.items():
        legacy = technique_step_cost("pipeshard", WL_M, c)
        tagged = technique_step_cost("pipeshard", WL_M, c,
                                     schedule="gpipe")
        assert (legacy.compute_s, legacy.comm_s, legacy.mem_required_gb) \
            == (tagged.compute_s, tagged.comm_s,
                tagged.mem_required_gb), name


def test_1f1b_same_time_less_memory_than_gpipe():
    for name, c in PAPER_CLUSTERS.items():
        gp = technique_step_cost("pipeshard", WL_M, c)
        f1b = technique_step_cost("pipeshard", WL_M, c, schedule="1f1b")
        assert f1b.total_s == gp.total_s, name
        assert f1b.mem_required_gb < gp.mem_required_gb, name  # m=4 > S=2


# ------------------------------------------------------------------ #
# the technique cost registry (docs/cost-model.md): the four paper
# specs must price bit-for-bit what the pre-refactor if/elif chain did
# ------------------------------------------------------------------ #

def _legacy_step_cost(technique, wl, cluster, vms=None, *,
                      stage_order=None, stage_balance="even",
                      stage_layers=None, schedule="gpipe"):
    """Frozen copy of the pre-registry ``technique_step_cost`` chain
    (PR-4 state), kept verbatim as the bit-for-bit oracle — including
    its own collective-time helpers, so the oracle shares no pricing
    code with the registry under test."""
    from repro.core.costmodel import as_topology

    def allreduce(bytes_total, n, link):
        if n <= 1:
            return 0.0
        return 2 * (n - 1) * link.latency_s \
            + 2 * (n - 1) / n * bytes_total / (link.effective_gbps * 1e9)

    def collective(bytes_total, n, topo, sites):
        if len(sites) <= 1:
            return allreduce(bytes_total, n, topo.sites[sites[0]].intra)
        return max(allreduce(bytes_total, n, l)
                   for l in topo.spanning_links(sites))

    topo = as_topology(cluster)
    sel = topo.select(vms)
    sites = [topo.sites[i] for i in sel]
    gpus = [GPUS[g] for s in sites for g in s.gpus]
    n = len(gpus)
    flops = wl.flops_per_step
    slowest = min(g.tflops for g in gpus) * 1e12
    g_bytes = wl.bytes_grads()
    p_bytes = wl.bytes_params()
    state = wl.bytes_train_state()
    act = wl.activation_bytes_per_gpu(n)
    ovh = wl.OVERHEAD_GB
    mem_avail = min(g.mem_gb for g in gpus)

    if technique == "data":
        compute = flops / (n * slowest)
        comm = collective(g_bytes, n, topo, sel)
        mem = (state + act) / 1e9 + ovh
    elif technique == "zero2":
        compute = flops / (n * slowest)
        comm = 2.2 * collective(g_bytes, n, topo, sel)
        mem = (p_bytes + (state - p_bytes) / n + act) / 1e9 + ovh
    elif technique == "shard":
        compute = flops / (n * slowest)
        act_bytes = wl.tokens_per_step * wl.cfg.d_model * 2
        comm = 4 * wl.cfg.n_layers * collective(act_bytes, n, topo, sel)
        mem = (state / n + 1.5 * act) / 1e9 + ovh
    elif technique == "pipeshard":
        order = sel if stage_order is None else topo.select(stage_order)
        n_stages = max(len(order), 1)
        kind, virt = parse_schedule(schedule)
        n_chunks = n_stages * virt
        stage_sites = [topo.sites[i] for i in order]
        stage_tf = stage_compute_tflops(topo, order)
        mesh_tflops = [t * 1e12 for t in stage_tf]
        bubble = pipeline_bubble_fraction(schedule, n_stages,
                                          wl.microbatches)
        if stage_layers is not None:
            split = tuple(stage_layers)
        elif stage_balance == "tflops":
            split = balanced_stage_layers(
                wl.cfg.n_layers,
                [stage_tf[c % n_stages] for c in range(n_chunks)])
        else:
            split = None
        if split is None:
            compute = max(flops / n_stages / t for t in mesh_tflops) \
                * (1 + bubble)
        else:
            stage_l = [sum(split[c] for c in range(n_chunks)
                           if c % n_stages == s) for s in range(n_stages)]
            compute = max(li / wl.cfg.n_layers * flops / t
                          for li, t in zip(stage_l, mesh_tflops)) \
                * (1 + bubble)
        act_bytes = wl.tokens_per_step * wl.cfg.d_model * 2
        p2p = sum(
            2 * (wl.microbatches * (act_bytes / wl.microbatches)
                 / (topo.link(a, b).effective_gbps * 1e9)
                 + wl.microbatches * topo.link(a, b).latency_s)
            for a, b in zip(order[:-1], order[1:]))
        if kind == "interleaved" and n_stages > 1:
            wrap = topo.link(order[-1], order[0])
            p2p = virt * p2p + (virt - 1) * 2 * (
                act_bytes / (wrap.effective_gbps * 1e9)
                + wl.microbatches * wrap.latency_s)
        if split is None:
            intra_comm = max(
                4 * wl.cfg.n_layers / n_stages * allreduce(
                    act_bytes, len(s.gpus), s.intra)
                for s in stage_sites)
        else:
            intra_comm = max(
                4 * li * allreduce(act_bytes, len(s.gpus), s.intra)
                for li, s in zip(stage_l, stage_sites))
        comm = p2p + intra_comm
        inflight = pipeline_inflight_microbatches(schedule, n_stages,
                                                  wl.microbatches)
        mem = (state / n + act * (1 + 0.5 * inflight)) / 1e9 + ovh
    else:
        raise ValueError(technique)
    return compute, comm, mem, mem_avail


def _topology_zoo():
    het = [Site(("A30", "A30"), name="A"), Site(("T4", "T4"), name="B"),
           Site(("RTX", "RTX"), name="C"), Site(("A30", "A30"), name="D")]
    return ([PAPER_CLUSTERS[n] for n in PAPER_CLUSTERS]
            + [line("l4", het, [Link(5e-3, 3.0), Link(50e-3, 1.0),
                                Link(0.5e-3, 3.0)]),
               ring("r4", het, [Link(5e-3, 3.0), Link(50e-3, 1.0),
                                Link(0.5e-3, 3.0), Link(90e-3, 2.0)])])


def test_registry_prices_paper_techniques_bit_for_bit():
    """The acceptance gate: every paper technique priced through the
    ``TECHNIQUE_SPECS`` registry is EXACTLY (``==``, not approx) the
    pre-refactor chain's number — subsets, stage orders, schedules,
    balances, explicit splits and all."""
    for cluster in _topology_zoo():
        from repro.core.costmodel import as_topology
        topo = as_topology(cluster)
        n = topo.n_sites
        for wl in (WL_M, WL_L, dataclasses.replace(WL_M, microbatches=8)):
            for tech in TECHNIQUES:
                for vms in [None] + [[i] for i in range(n)]:
                    got = technique_step_cost(tech, wl, cluster, vms)
                    want = _legacy_step_cost(tech, wl, cluster, vms)
                    assert (got.compute_s, got.comm_s,
                            got.mem_required_gb,
                            got.mem_available_gb) == want, (tech, vms)
            sel = list(range(min(n, 3)))
            for sched in ("gpipe", "1f1b", "interleaved", "interleaved3"):
                for bal in ("even", "tflops"):
                    for order in itertools.permutations(sel):
                        got = technique_step_cost(
                            "pipeshard", wl, cluster, sel,
                            stage_order=order, stage_balance=bal,
                            schedule=sched)
                        want = _legacy_step_cost(
                            "pipeshard", wl, cluster, sel,
                            stage_order=order, stage_balance=bal,
                            schedule=sched)
                        assert (got.compute_s, got.comm_s,
                                got.mem_required_gb,
                                got.mem_available_gb) == want, \
                            (sched, bal, order)


@settings(max_examples=30, deadline=None)
@given(model=st.sampled_from(["gpt2m", "gpt2L"]),
       gb=st.sampled_from([16, 32, 52]),
       micro=st.sampled_from([2, 4, 8]),
       gpus=st.lists(st.sampled_from(["RTX", "T4", "A30"]),
                     min_size=3, max_size=3),
       lats=st.lists(st.floats(0.05, 150.0), min_size=3, max_size=3),
       tech=st.sampled_from(TECHNIQUES),
       sched=st.sampled_from(["gpipe", "1f1b", "interleaved"]),
       bal=st.sampled_from(["even", "tflops"]))
def test_registry_matches_legacy_chain_property(model, gb, micro, gpus,
                                                lats, tech, sched, bal):
    """Registry == legacy chain, bit-for-bit, over random workloads and
    topologies (the tentpole's refactor-safety property)."""
    wl = dataclasses.replace(paper_workload(get_config(model),
                                            global_batch=gb),
                             microbatches=micro)
    topo = ring("t", [Site((g, g), name=f"S{i}")
                      for i, g in enumerate(gpus)],
                [Link(l * 1e-3, 3.0) for l in lats])
    for vms in (None, [0], [0, 2]):
        if tech == "pipeshard" and vms is not None and len(vms) < 2:
            continue
        got = technique_step_cost(tech, wl, topo, vms, schedule=sched,
                                  stage_balance=bal)
        want = _legacy_step_cost(tech, wl, topo, vms, schedule=sched,
                                 stage_balance=bal)
        assert (got.compute_s, got.comm_s, got.mem_required_gb,
                got.mem_available_gb) == want


def test_unknown_technique_raises_with_registry_listing():
    with pytest.raises(ValueError, match="unknown technique"):
        technique_step_cost("ddp", WL_M, PAPER_CLUSTERS["TACC-TACC"])


def test_register_technique_rejects_duplicates():
    spec = TECHNIQUE_SPECS["data"]
    with pytest.raises(ValueError, match="already registered"):
        register_technique(spec)
    assert register_technique(spec, replace=True) is spec


# ------------------------------------------------------------------ #
# the beyond-paper specs: memory fractions, orderings, carrier dtype
# ------------------------------------------------------------------ #

def test_all_techniques_extend_paper_pool():
    assert ALL_TECHNIQUES[:4] == TECHNIQUES
    assert set(ALL_TECHNIQUES) == set(TECHNIQUE_SPECS)
    assert all(TECHNIQUE_SPECS[t].paper == (t in TECHNIQUES)
               for t in ALL_TECHNIQUES)


def test_state_bytes_ordering_fsdp_lowest():
    """fsdp <= shard_zero <= zero2 <= data state bytes, on every paper
    cluster and multi-GPU selection (the ZeRO ladder: each stage
    partitions strictly more of the train state)."""
    tol = 1 + 1e-12
    for cluster in _topology_zoo():
        for wl in (WL_M, WL_L):
            f, sz, z2, d = (technique_state_bytes(t, wl, cluster)
                            for t in ("fsdp", "shard_zero", "zero2",
                                      "data"))
            assert f <= sz * tol <= z2 * tol ** 2 <= d * tol ** 3
            assert d == wl.bytes_train_state()


def test_state_bytes_monotone_non_increasing_in_pool_size():
    """Adding sites (growing n) never increases any technique's per-GPU
    state bytes."""
    for wl in (WL_M, WL_L):
        for tech in ("data", "zero2", "shard_zero", "fsdp"):
            prev = None
            for n in (2, 3, 4, 6):
                sites = [Site(("A30", "A30"), name=f"S{i}")
                         for i in range(n)]
                topo = make_topology("t", sites, {
                    (i, j): Link(1e-3, 3.0)
                    for i, j in itertools.combinations(range(n), 2)})
                b = technique_state_bytes(tech, wl, topo)
                if prev is not None:
                    assert b <= prev * (1 + 1e-12), (tech, n)
                prev = b


def test_memory_model_rejects_unsupported_placement():
    from repro.core.costmodel import _make_context
    ctx = _make_context(WL_M, PAPER_CLUSTERS["TACC-TACC"], None)
    with pytest.raises(ValueError, match="unsupported memory placement"):
        MemoryModel("pool", "replicated").state_bytes(ctx)


def test_fsdp_memory_below_zero2_and_shard():
    """The fsdp spec is the lowest-memory plan everywhere — the plan
    that revives memory-tight selections (docs/cost-model.md)."""
    for cluster in _topology_zoo():
        for wl in (WL_M, WL_L):
            mems = {t: technique_step_cost(t, wl, cluster).mem_required_gb
                    for t in ALL_TECHNIQUES}
            assert mems["fsdp"] <= min(mems.values()) * (1 + 1e-12)


def test_carrier_dtype_scales():
    assert carrier_scale("fp32") == 1.0
    assert carrier_scale("bf16") == 0.5
    with pytest.raises(ValueError):
        carrier_scale("fp16")
    with pytest.raises(ValueError):
        technique_step_cost("pipeshard", WL_M,
                            PAPER_CLUSTERS["TACC-TACC"],
                            carrier_dtype="int8")


def test_bf16_carrier_halves_p2p_bytes_exactly():
    """On zero-latency links between single-GPU sites (no intra-op
    all-reduces, no latency rounds) the Pipeshard comm term is pure p2p
    bytes, so the bf16 carrier prices exactly half of fp32."""
    sites = [Site(("A30",), name=f"S{i}") for i in range(3)]
    topo = line("z", sites, [Link(0.0, 3.0)] * 2)
    for sched in ("gpipe", "1f1b", "interleaved"):
        fp32 = technique_step_cost("pipeshard", WL_M, topo,
                                   schedule=sched)
        bf16 = technique_step_cost("pipeshard", WL_M, topo,
                                   schedule=sched, carrier_dtype="bf16")
        assert bf16.comm_s == fp32.comm_s / 2, sched
        assert bf16.compute_s == fp32.compute_s
        assert bf16.mem_required_gb == fp32.mem_required_gb


def test_carrier_dtype_only_touches_pipeshard_p2p():
    """Collective techniques ignore the carrier knob, and a pipeline's
    latency rounds and intra-op all-reduces are carrier-invariant."""
    c = PAPER_CLUSTERS["UTAH-MASS"]
    for tech in ("data", "zero2", "shard", "shard_zero", "fsdp"):
        a = technique_step_cost(tech, WL_M, c)
        b = technique_step_cost(tech, WL_M, c, carrier_dtype="bf16")
        assert (a.compute_s, a.comm_s, a.mem_required_gb) \
            == (b.compute_s, b.comm_s, b.mem_required_gb), tech
    a = technique_step_cost("pipeshard", WL_M, c)
    b = technique_step_cost("pipeshard", WL_M, c, carrier_dtype="bf16")
    assert b.comm_s < a.comm_s                  # cheaper, not free
    assert b.comm_s > a.comm_s / 2              # latency + intra remain


def test_shard_zero_degenerates_to_shard_on_one_site():
    """With a single participating site the hybrid's inter-site ZeRO
    term vanishes and its intra term is exactly shard's."""
    c = PAPER_CLUSTERS["TACC-TACC"]
    sz = technique_step_cost("shard_zero", WL_M, c, [0])
    sh = technique_step_cost("shard", WL_M, c, [0])
    assert sz.comm_s == sh.comm_s
    assert sz.compute_s == sh.compute_s
    assert sz.mem_required_gb == pytest.approx(sh.mem_required_gb)


def test_shard_zero_cheaper_collectives_than_zero2_multi_site():
    """The hybrid's point: TP inside each site keeps the per-layer
    all-reduces off the WAN, and its cross-site ZeRO volume is 1/tp of
    zero2's — so on every multi-site paper slice it out-prices zero2's
    comm term."""
    for name in MULTI_SITE:
        c = PAPER_CLUSTERS[name]
        sz = technique_step_cost("shard_zero", WL_M, c)
        z2 = technique_step_cost("zero2", WL_M, c)
        assert sz.comm_s < z2.comm_s, name


def test_fsdp_latency_bound_on_wan():
    """fsdp pays 2L+1 latency rounds, so its comm degrades with WAN RTT
    far faster than zero2's — it is a LAN/single-site plan."""
    lo = fabric_cluster("lo", ("A30", "A30"), ("A30", "A30"), 0.1)
    hi = fabric_cluster("hi", ("A30", "A30"), ("A30", "A30"), 103.0)
    deg = lambda t: technique_step_cost(t, WL_M, hi).comm_s \
        / technique_step_cost(t, WL_M, lo).comm_s
    assert deg("fsdp") > deg("zero2")


def test_interleaved_prices_the_wrap_link():
    """On a line, the interleaved ring's wrap-around (last stage back to
    first) is the expensive multi-hop return path: making the middle
    edge dearer must hit the interleaved pipeline harder than GPipe."""
    import dataclasses
    from repro.core.topology import Link, Site, line
    wl = dataclasses.replace(WL_M, microbatches=2)
    sites = [Site(("A30", "A30"), name=f"S{i}") for i in range(3)]
    cheap = line("c", sites, [Link(0.1e-3, 3.0)] * 2)
    dear = line("d", sites, [Link(40e-3, 3.0)] * 2)
    d_gp = technique_step_cost("pipeshard", wl, dear).comm_s \
        - technique_step_cost("pipeshard", wl, cheap).comm_s
    d_il = technique_step_cost("pipeshard", wl, dear,
                               schedule="interleaved").comm_s \
        - technique_step_cost("pipeshard", wl, cheap,
                              schedule="interleaved").comm_s
    assert d_il > d_gp


# --------------------------------------------------------------------- #
# the wire_dtype axis (docs/quantization.md): quantized collective and
# p2p payloads with an fp32-master-weights correction term
# --------------------------------------------------------------------- #

def test_wire_scale_values():
    from repro.core.costmodel import WIRE_DTYPES, wire_scale
    assert WIRE_DTYPES == ("fp32", "bf16", "int8")
    assert wire_scale("fp32") == 1.0
    assert wire_scale("bf16") == 0.5
    # int8 payload + fp32 per-128-block absmax scale: (128+4)/(128*4)
    assert wire_scale("int8") == 0.2578125
    for bad in ("fp16", "int4", "fp8"):
        with pytest.raises(ValueError):
            wire_scale(bad)


def test_fp32_wire_is_bit_for_bit_legacy():
    """wire_dtype='fp32' must be the identity — every component of every
    technique's step cost equals the no-kwarg pricing exactly, on all
    paper clusters and the topology zoo."""
    for cluster in list(PAPER_CLUSTERS.values()) + _topology_zoo():
        for wl in (WL_M, WL_L):
            for tech in ALL_TECHNIQUES:
                a = technique_step_cost(tech, wl, cluster)
                b = technique_step_cost(tech, wl, cluster,
                                        wire_dtype="fp32")
                assert (a.compute_s, a.comm_s, a.total_s,
                        a.mem_required_gb) \
                    == (b.compute_s, b.comm_s, b.total_s,
                        b.mem_required_gb), tech


def test_wire_dtype_monotone_and_compute_invariant():
    """Cheaper wire dtypes price a strictly cheaper comm term on WAN
    clusters (fp32 > bf16 > int8) and never touch compute or memory."""
    c = PAPER_CLUSTERS["UTAH-GPN"]
    for tech in ALL_TECHNIQUES:
        costs = {wd: technique_step_cost(tech, WL_M, c, wire_dtype=wd)
                 for wd in ("fp32", "bf16", "int8")}
        assert costs["fp32"].comm_s > costs["bf16"].comm_s \
            > costs["int8"].comm_s, tech
        assert len({r.compute_s for r in costs.values()}) == 1, tech
        assert len({r.mem_required_gb for r in costs.values()}) == 1, tech


def test_eff_byte_scale_master_weight_correction():
    """_eff_byte_scale: the quantizable fraction rides the wire scale,
    the remainder (fp32 master-weight sync) stays full fat — and
    ws == 1.0 short-circuits to the literal 1.0 (fp32 exactness)."""
    from repro.core.costmodel import CommPrecision, _eff_byte_scale
    assert _eff_byte_scale(0.3, 1.0) == 1.0
    assert _eff_byte_scale(1.0, 0.25) == 0.25
    assert _eff_byte_scale(0.5, 0.25) == 0.5 * 0.25 + 0.5
    # defaults: everything quantizable
    cp = CommPrecision()
    assert cp.act == 1.0 and cp.state == 1.0


def test_zero2_wire_saving_capped_by_master_share():
    """zero2's grad bucket is 2.0 of its 2.2x volume — the 0.2x
    master-sync share stays fp32, so the int8 comm saving is strictly
    smaller than data's (whose volume quantizes fully).  On a
    zero-latency link the ratios are exact byte ratios."""
    from repro.core.costmodel import wire_scale
    sites = [Site(("A30",), name=f"S{i}") for i in range(2)]
    topo = line("z", sites, [Link(0.0, 3.0)])
    ratio = {}
    for tech, frac in (("data", 1.0), ("zero2", 2.0 / 2.2)):
        q = technique_step_cost(tech, WL_M, topo, wire_dtype="int8")
        f = technique_step_cost(tech, WL_M, topo)
        ratio[tech] = q.comm_s / f.comm_s
        want = frac * wire_scale("int8") + (1.0 - frac)
        assert ratio[tech] == pytest.approx(want, rel=1e-12), tech
    assert ratio["data"] < ratio["zero2"]


def test_int8_wire_stacks_with_carrier_dtype():
    """A pipeline's p2p carrier rides min(carrier, wire): int8 wire on
    top of a bf16 carrier prices the int8 p2p bytes, never more."""
    c = PAPER_CLUSTERS["UTAH-MASS"]
    both = technique_step_cost("pipeshard", WL_M, c, wire_dtype="int8",
                               carrier_dtype="bf16")
    wire_only = technique_step_cost("pipeshard", WL_M, c,
                                    wire_dtype="int8")
    assert both.comm_s == wire_only.comm_s
    bf16 = technique_step_cost("pipeshard", WL_M, c, carrier_dtype="bf16")
    assert both.comm_s < bf16.comm_s
