"""Paper-validation tests: the cost model must reproduce the paper's
claims C1–C5 (orderings, latency degradation, OOM boundaries, Algorithm 1
selections) — these are the EXPERIMENTS.md §Paper-validation gates."""
import numpy as np
import pytest
from prophelpers import given, settings, st

from repro.configs import get_config
from repro.core.costmodel import (GPUS, PAPER_CLUSTERS, SCHEDULES, Cluster,
                                  Link, VM, avg_tflops, epoch_minutes,
                                  fabric_cluster, paper_workload,
                                  parse_schedule,
                                  pipeline_bubble_fraction,
                                  pipeline_inflight_microbatches,
                                  technique_step_cost)
from repro.core.selector import CostModelProber, select_technique

WL_M = paper_workload(get_config("gpt2m"))
WL_L = paper_workload(get_config("gpt2L"))
MULTI_SITE = ["UTAH-GPN", "UTAH-MASS", "BRIS-STAR", "GAT-AMST"]


def test_c1_pipeshard_fastest_when_geo_distributed():
    for name in MULTI_SITE:
        c = PAPER_CLUSTERS[name]
        times = {t: epoch_minutes(t, WL_M, c)
                 for t in ("data", "zero2", "shard", "pipeshard")}
        ran = {k: v for k, v in times.items() if v is not None}
        assert min(ran, key=ran.get) == "pipeshard", (name, times)


def test_c2_shard_degrades_worst_with_latency():
    degr = {}
    for t in ("data", "zero2", "shard", "pipeshard"):
        t0 = epoch_minutes(t, WL_M, PAPER_CLUSTERS["TACC-TACC"])
        t4 = epoch_minutes(t, WL_M, PAPER_CLUSTERS["GAT-AMST"])
        degr[t] = t4 / t0
    assert degr["shard"] == max(degr.values())
    assert degr["pipeshard"] == min(degr.values())
    # paper magnitudes: pipeshard ~3.4x, shard ~66x
    assert degr["pipeshard"] < 5
    assert degr["shard"] > 20


def test_c3_single_vm_data_beats_pipeshard_on_fast_island():
    c = PAPER_CLUSTERS["TACC-TACC"]
    one_vm = avg_tflops("data", WL_M, c, vms=[0])
    four = avg_tflops("pipeshard", WL_M, c)
    assert one_vm > four  # paper: 15.74 vs 12.17 TFLOP/s


def test_c4_zero2_is_the_low_memory_fallback():
    """gpt2L on the T4-limited clusters: ZeRO2 fits where data/pipeshard
    don't (paper Figs 3-4)."""
    for name in ("TACC-TACC", "UTAH-GPN"):
        c = PAPER_CLUSTERS[name]
        fits = {t: technique_step_cost(t, WL_L, c).fits
                for t in ("data", "zero2", "pipeshard")}
        assert fits["zero2"], name
        assert not fits["data"], name
        assert not fits["pipeshard"], name


def test_c4b_pipeshard_fits_on_24gb_cluster():
    c = PAPER_CLUSTERS["UTAH-MASS"]  # 4x RTX 24GB
    assert technique_step_cost("pipeshard", WL_L, c).fits
    assert technique_step_cost("data", WL_L, c).fits


def test_c5_algorithm1_selections_match_paper():
    import benchmarks.paper_alg1 as alg
    assert alg.run(print_fn=lambda *_: None) == 0


def test_paper_benchmark_claims_pass():
    import benchmarks.paper_figs as figs
    import benchmarks.paper_table2 as t2
    assert figs.run(print_fn=lambda *_: None) == 0
    assert t2.run(print_fn=lambda *_: None) == 0


@settings(max_examples=25, deadline=None)
@given(
    lat1=st.floats(0.1, 50.0),
    lat2=st.floats(50.1, 150.0),
)
def test_latency_monotonicity_property(lat1, lat2):
    """More latency never speeds anything up, and pipeshard's degradation
    ratio is always <= data's (the paper's central finding)."""
    c1 = fabric_cluster("lo", ("RTX", "RTX"), ("RTX", "RTX"), lat1)
    c2 = fabric_cluster("hi", ("RTX", "RTX"), ("RTX", "RTX"), lat2)
    for tech in ("data", "zero2", "shard", "pipeshard"):
        t1 = technique_step_cost(tech, WL_M, c1).total_s
        t2_ = technique_step_cost(tech, WL_M, c2).total_s
        assert t2_ >= t1 * 0.999, tech
    deg = lambda t: technique_step_cost(t, WL_M, c2).total_s \
        / technique_step_cost(t, WL_M, c1).total_s
    assert deg("pipeshard") <= deg("data") * 1.001


@settings(max_examples=15, deadline=None)
@given(lat=st.floats(0.1, 150.0))
def test_selector_always_returns_feasible_or_none(lat):
    c = fabric_cluster("x", ("A30", "A30"), ("T4", "T4"), lat)
    sel = select_technique(CostModelProber(WL_M, c), delta=0.1)
    assert sel.technique in ("data", "zero2", "shard", "pipeshard", "none")
    if sel.technique != "none":
        assert sel.vms is not None


def test_heterogeneous_cluster_paced_by_slowest():
    """Data parallel with a T4 in the pool is slower than all-A30."""
    fast = fabric_cluster("f", ("A30", "A30"), ("A30", "A30"), 1.0)
    slow = fabric_cluster("s", ("A30", "A30"), ("T4", "T4"), 1.0)
    assert technique_step_cost("data", WL_M, slow).compute_s > \
        technique_step_cost("data", WL_M, fast).compute_s


# ------------------------------------------------------------------ #
# pipeline schedules (docs/schedules.md): bubble and memory terms
# ------------------------------------------------------------------ #

def test_parse_schedule():
    assert parse_schedule("gpipe") == ("gpipe", 1)
    assert parse_schedule("1f1b") == ("1f1b", 1)
    assert parse_schedule("interleaved") == ("interleaved", 2)
    assert parse_schedule("interleaved4") == ("interleaved", 4)
    for bad in ("INTERLEAVED", "interleaved1", "interleavedx", "1F1B"):
        with pytest.raises(ValueError):
            parse_schedule(bad)


@settings(max_examples=50, deadline=None)
@given(S=st.integers(1, 8), m=st.integers(1, 32), v=st.integers(2, 4))
def test_schedule_bubble_property(S, m, v):
    """1F1B's bubble equals GPipe's; the interleaved schedule divides it
    by v (strictly shallower whenever there is a bubble at all)."""
    gp = pipeline_bubble_fraction("gpipe", S, m)
    assert gp == (S - 1) / m
    assert pipeline_bubble_fraction("1f1b", S, m) == gp
    il = pipeline_bubble_fraction(f"interleaved{v}", S, m)
    assert il == pytest.approx(gp / v)
    if S > 1:
        assert il < gp


@settings(max_examples=50, deadline=None)
@given(S=st.integers(1, 8), m=st.integers(1, 32))
def test_schedule_memory_property(S, m):
    """1F1B never stashes more than GPipe (strictly less once m > S);
    interleaving costs a little above 1F1B; and every schedule's
    in-flight count is monotone non-decreasing in m."""
    gp = pipeline_inflight_microbatches("gpipe", S, m)
    f1b = pipeline_inflight_microbatches("1f1b", S, m)
    il = pipeline_inflight_microbatches("interleaved", S, m)
    assert gp == m
    assert f1b == min(S, m) <= gp
    if m > S:
        assert f1b < gp
    assert f1b <= il
    for sched in SCHEDULES:
        a = pipeline_inflight_microbatches(sched, S, m)
        b = pipeline_inflight_microbatches(sched, S, m + 1)
        assert b >= a, sched


def test_gpipe_schedule_is_the_legacy_cost_bit_for_bit():
    """schedule="gpipe" must keep every paper number: same bubble term,
    same m-in-flight memory, no p2p multiplier."""
    for name, c in PAPER_CLUSTERS.items():
        legacy = technique_step_cost("pipeshard", WL_M, c)
        tagged = technique_step_cost("pipeshard", WL_M, c,
                                     schedule="gpipe")
        assert (legacy.compute_s, legacy.comm_s, legacy.mem_required_gb) \
            == (tagged.compute_s, tagged.comm_s,
                tagged.mem_required_gb), name


def test_1f1b_same_time_less_memory_than_gpipe():
    for name, c in PAPER_CLUSTERS.items():
        gp = technique_step_cost("pipeshard", WL_M, c)
        f1b = technique_step_cost("pipeshard", WL_M, c, schedule="1f1b")
        assert f1b.total_s == gp.total_s, name
        assert f1b.mem_required_gb < gp.mem_required_gb, name  # m=4 > S=2


def test_interleaved_prices_the_wrap_link():
    """On a line, the interleaved ring's wrap-around (last stage back to
    first) is the expensive multi-hop return path: making the middle
    edge dearer must hit the interleaved pipeline harder than GPipe."""
    import dataclasses
    from repro.core.topology import Link, Site, line
    wl = dataclasses.replace(WL_M, microbatches=2)
    sites = [Site(("A30", "A30"), name=f"S{i}") for i in range(3)]
    cheap = line("c", sites, [Link(0.1e-3, 3.0)] * 2)
    dear = line("d", sites, [Link(40e-3, 3.0)] * 2)
    d_gp = technique_step_cost("pipeshard", wl, dear).comm_s \
        - technique_step_cost("pipeshard", wl, cheap).comm_s
    d_il = technique_step_cost("pipeshard", wl, dear,
                               schedule="interleaved").comm_s \
        - technique_step_cost("pipeshard", wl, cheap,
                              schedule="interleaved").comm_s
    assert d_il > d_gp
