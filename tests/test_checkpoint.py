"""Checkpoint roundtrip + durability tests: atomic saves, sha256 shard
integrity, and the no-silent-dtype-cast restore contract
(docs/elasticity.md — the chaos-recovery path leans on all three)."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from prophelpers import given, settings, st
from repro.configs import get_config
from repro.models import Model
from repro.optim import init_adamw
from repro.train import (latest_checkpoint, restore_checkpoint,
                         save_checkpoint, verify_checkpoint)


def test_roundtrip_params_and_opt(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = init_adamw(params)
    path = save_checkpoint(str(tmp_path), 7, params, opt, n_files=3)
    p2, o2, step = restore_checkpoint(path, params, opt)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), opt, o2)


def test_latest_checkpoint_ordering(tmp_path):
    cfg = get_config("whisper-small").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    save_checkpoint(str(tmp_path), 5, params)
    save_checkpoint(str(tmp_path), 50, params)
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000050")


def test_restore_rejects_shape_mismatch(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    path = save_checkpoint(str(tmp_path), 1, params)
    import dataclasses
    bad_cfg = dataclasses.replace(cfg, d_ff=cfg.d_ff * 2)
    bad = Model(bad_cfg).init(jax.random.key(0))
    try:
        restore_checkpoint(path, bad)
        raise AssertionError("expected shape mismatch")
    except ValueError:
        pass


# ------------------------------------------------------------------ #
# no silent dtype casts
# ------------------------------------------------------------------ #

def test_restore_rejects_dtype_mismatch_unless_allow_cast(tmp_path):
    """Regression: a saved fp32 master leaf restored onto a bf16
    template used to downcast silently, destroying master-weight
    precision."""
    params = {"w": jnp.ones((4, 4), jnp.float32) * (1 + 2 ** -20)}
    path = save_checkpoint(str(tmp_path), 1, params)
    bf16_like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    with pytest.raises(ValueError, match="allow_cast"):
        restore_checkpoint(path, bf16_like)
    p2, _, _ = restore_checkpoint(path, bf16_like, allow_cast=True)
    assert p2["w"].dtype == jnp.bfloat16         # deliberate cast works
    p3, _, _ = restore_checkpoint(path, params)  # matching dtype is exact
    np.testing.assert_array_equal(np.asarray(p3["w"]),
                                  np.asarray(params["w"]))


# ------------------------------------------------------------------ #
# atomic saves + integrity
# ------------------------------------------------------------------ #

def test_partial_save_is_invisible_and_fails_loudly(tmp_path):
    """A crash mid-save (a step_* dir without a fsynced manifest, or a
    .tmp staging dir) must be skipped by latest_checkpoint and refuse
    to restore."""
    params = {"w": jnp.ones((2, 2))}
    good = save_checkpoint(str(tmp_path), 3, params)
    # simulate a crash: a staging dir and a manifest-less partial
    os.makedirs(tmp_path / "step_00000009.tmp")
    partial = tmp_path / "step_00000007"
    os.makedirs(partial)
    np.savez(partial / "params_00.npz", w=np.ones((2, 2)))
    assert latest_checkpoint(str(tmp_path)) == good
    with pytest.raises(ValueError, match="manifest"):
        restore_checkpoint(str(partial), params)
    with pytest.raises(ValueError, match="manifest"):
        verify_checkpoint(str(partial))


def test_save_leaves_no_staging_dir_and_resaves_steps(tmp_path):
    params = {"w": jnp.ones((2, 2))}
    save_checkpoint(str(tmp_path), 4, params)
    path = save_checkpoint(str(tmp_path), 4, params)   # re-save same step
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert verify_checkpoint(path)["step"] == 4


def test_truncated_shard_fails_checksum(tmp_path):
    params = {"w": jnp.arange(64, dtype=jnp.float32)}
    opt = init_adamw(params)
    path = save_checkpoint(str(tmp_path), 2, params, opt, n_files=2)
    shard = next(f for f in os.listdir(path) if f.endswith(".npz"))
    with open(os.path.join(path, shard), "r+b") as f:
        f.truncate(os.path.getsize(os.path.join(path, shard)) - 7)
    with pytest.raises(ValueError, match="sha256"):
        verify_checkpoint(path)
    with pytest.raises(ValueError, match="sha256"):
        restore_checkpoint(path, params, opt)


def test_missing_shard_fails_verification(tmp_path):
    params = {"a": jnp.ones(3), "b": jnp.zeros(5)}
    path = save_checkpoint(str(tmp_path), 2, params, n_files=2)
    shards = [f for f in os.listdir(path) if f.endswith(".npz")]
    assert len(shards) == 2
    os.remove(os.path.join(path, shards[0]))
    with pytest.raises(ValueError, match="missing"):
        verify_checkpoint(path)


def test_corrupt_shard_fails_checksum_but_skippable(tmp_path):
    """Flipping bytes past the npz header trips sha256; verify=False is
    the explicit escape hatch (np.load may still read stale values)."""
    params = {"w": jnp.arange(1024, dtype=jnp.float32)}
    path = save_checkpoint(str(tmp_path), 1, params, n_files=1)
    shard = next(f for f in os.listdir(path) if f.endswith(".npz"))
    with open(os.path.join(path, shard), "ab") as f:
        f.write(b"garbage")
    with pytest.raises(ValueError, match="sha256"):
        restore_checkpoint(path, params)
    p2, _, _ = restore_checkpoint(path, params, verify=False)
    assert p2["w"].shape == (1024,)


def test_legacy_manifest_without_checksums_still_verifies_existence(
        tmp_path):
    params = {"w": jnp.ones(4)}
    path = save_checkpoint(str(tmp_path), 1, params)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["checksums"]                    # pre-integrity manifest
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    verify_checkpoint(path)                      # existence-only: passes
    shard = manifest["files"]["params"][0]
    os.remove(os.path.join(path, shard))
    with pytest.raises(ValueError, match="missing"):
        verify_checkpoint(path)


# ------------------------------------------------------------------ #
# property: any pytree x any shard count round-trips
# ------------------------------------------------------------------ #

_KEY = st.text(alphabet="abcdefghij_0123456789", min_size=1, max_size=8)
_LEAF = st.tuples(
    st.sampled_from([np.float32, np.int32, np.float16]),
    st.lists(st.integers(1, 4), min_size=0, max_size=3))


def _tree_strategy():
    return st.recursive(
        st.dictionaries(_KEY, _LEAF, min_size=1, max_size=3),
        lambda children: st.dictionaries(_KEY, children, min_size=1,
                                         max_size=2),
        max_leaves=6)


@settings(max_examples=20, deadline=None)
@given(tree=_tree_strategy(), n_files=st.sampled_from([1, 2, 4, 7]),
       seed=st.integers(0, 99))
def test_checkpoint_roundtrip_property(tree, n_files, seed):
    """Random nested pytrees round-trip bit-exactly through save/restore
    for any shard count — including n_files larger than the leaf count
    (empty shards are simply not written)."""
    rng = np.random.default_rng(seed)

    def materialize(node):
        if isinstance(node, dict):
            return {k: materialize(v) for k, v in node.items()}
        dtype, shape = node
        arr = rng.standard_normal(tuple(shape)) * 10
        return arr.astype(dtype)

    params = materialize(tree)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 11, params, n_files=n_files)
        manifest = verify_checkpoint(path)
        assert set(manifest["checksums"]) == {
            f for fs in manifest["files"].values() for f in fs}
        p2, _, step = restore_checkpoint(path, params)
        assert step == 11
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), params, p2)
