"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.optim import init_adamw
from repro.train import (latest_checkpoint, restore_checkpoint,
                         save_checkpoint)


def test_roundtrip_params_and_opt(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = init_adamw(params)
    path = save_checkpoint(str(tmp_path), 7, params, opt, n_files=3)
    p2, o2, step = restore_checkpoint(path, params, opt)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), opt, o2)


def test_latest_checkpoint_ordering(tmp_path):
    cfg = get_config("whisper-small").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    save_checkpoint(str(tmp_path), 5, params)
    save_checkpoint(str(tmp_path), 50, params)
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000050")


def test_restore_rejects_shape_mismatch(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    path = save_checkpoint(str(tmp_path), 1, params)
    import dataclasses
    bad_cfg = dataclasses.replace(cfg, d_ff=cfg.d_ff * 2)
    bad = Model(bad_cfg).init(jax.random.key(0))
    try:
        restore_checkpoint(path, bad)
        raise AssertionError("expected shape mismatch")
    except ValueError:
        pass
