"""Elastic re-planning (ROADMAP "Elastic re-planning"): the survivor
search must respect connectivity (components), compose its index maps
back to the original topology, and the launcher must drive the full
kill → replan → reshard → resume path.

Analytic tests run the search layer only (no devices); the slow test
drives ``repro.launch.replan`` as a subprocess in both modes (chaos
demo, then checkpoint recovery on the degraded topology)."""
import json
import subprocess
import sys

import pytest

from repro.configs import get_config
from repro.core.costmodel import paper_workload
from repro.core.topology import Link, Site, fully_connected, line, ring
from repro.launch.replan import build_cli_topology, parse_gpus
from repro.train.replan import (SiteFailure, kill_site_at,
                                placement_devices, replan,
                                site_device_blocks)

WL = paper_workload(get_config("gpt2m"))


def _sites(n, gpu="A30"):
    return [Site((gpu, gpu), name=f"S{i}") for i in range(n)]


# ------------------------------------------------------------------ #
# fault injection
# ------------------------------------------------------------------ #

def test_kill_site_at_fires_only_at_its_step():
    hook = kill_site_at(3, (1,))
    for i in (0, 1, 2, 4):
        hook(i)                                  # no-op off the step
    with pytest.raises(SiteFailure) as e:
        hook(3)
    assert e.value.step == 3
    assert e.value.dead_sites == (1,)
    assert "V2" in str(e.value)


# ------------------------------------------------------------------ #
# the survivor search
# ------------------------------------------------------------------ #

def test_replan_ring_survivors_stay_connected():
    topo = ring("r3", _sites(3), [Link(20e-3, 3.0)] * 3)
    rp = replan(topo, (1,), WL)
    assert rp.dead_sites == (1,)
    # the winner's sites map back to surviving original indices
    assert set(rp.sites_old) <= {0, 2}
    assert rp.tflops > 0
    assert rp.search_s >= 0
    # placement indexes the searched sub-topology, not the original
    assert all(s < rp.topology.n_sites for s in rp.placement.sites)


def test_replan_line_kill_middle_splits_components():
    """Killing the middle site of a line disconnects the ends; the
    replan must place within one component — never span the partition."""
    topo = line("l3", _sites(3), [Link(20e-3, 3.0)] * 2)
    survivor, kept = topo.without_sites((1,))
    assert kept == (0, 2)
    assert survivor.components() == [(0,), (1,)]
    rp = replan(topo, (1,), WL)
    assert len(rp.placement.sites) == 1          # single-site winner only
    assert rp.sites_old in ((0,), (2,))
    assert rp.technique != "pipeshard"           # 1 site can't pipeline


def test_replan_heterogeneous_prefers_faster_survivor():
    """A30 vs T4 ends of a severed line: the search should land on the
    strictly faster component."""
    topo = line("het", [Site(("A30", "A30")), Site(("A30", "A30")),
                        Site(("T4", "T4"))], [Link(20e-3, 3.0)] * 2)
    rp = replan(topo, (1,), WL)
    assert rp.sites_old == (0,)                  # the A30 site wins


def test_replan_validates_and_raises_when_nothing_fits():
    topo = ring("r3", _sites(3), [Link(20e-3, 3.0)] * 3)
    with pytest.raises(ValueError, match="nothing to do"):
        replan(topo, (), WL)
    with pytest.raises(ValueError, match="died"):
        replan(topo, (0, 1, 2), WL)
    # a 405B model fits nowhere on two-GPU sites: every candidate OOMs
    big = paper_workload(get_config("llama3-405b"))
    with pytest.raises(RuntimeError, match="memory"):
        replan(topo, (1,), big)


# ------------------------------------------------------------------ #
# device-block bookkeeping
# ------------------------------------------------------------------ #

def test_site_device_blocks_follow_site_order():
    topo = fully_connected("f", _sites(3), Link(20e-3, 3.0))
    devs = list(range(6))                        # any objects work
    blocks = site_device_blocks(topo, devs)
    assert blocks == [(0, 1), (2, 3), (4, 5)]
    # a replanned placement re-uses its original sites' devices
    assert placement_devices(blocks, (2, 0)) == [4, 5, 0, 1]
    with pytest.raises(ValueError, match="devices"):
        site_device_blocks(topo, devs[:5])


# ------------------------------------------------------------------ #
# launcher plumbing
# ------------------------------------------------------------------ #

def test_cli_gpu_spec_parsing():
    assert parse_gpus("A30,A30;T4") == [("A30", "A30"), ("T4",)]
    with pytest.raises(ValueError, match="empty"):
        parse_gpus(" ; ")


def test_cli_topology_kinds():
    t = build_cli_topology("line", "A30;A30;T4", 20.0, 3.0)
    assert t.n_sites == 3 and (0, 2) not in t.links
    t = build_cli_topology("full", "A30;T4", 20.0, 3.0)
    assert t.n_sites == 2 and t.link(0, 1).latency_s == pytest.approx(
        20e-3)
    with pytest.raises(ValueError, match="unknown"):
        build_cli_topology("mesh", "A30;A30", 20.0, 3.0)


@pytest.mark.slow
def test_replan_launcher_chaos_then_recovery(subproc_env, tmp_path):
    """End-to-end through the CLI: (1) chaos-demo mode kills site V2 of
    a two-site pipeshard run and recovers; (2) recovery mode picks up
    the checkpoints the first run left and resumes further on the
    degraded topology."""
    common = ["--ckpt-dir", str(tmp_path), "--gpus", "A30;A30",
              "--kind", "full", "--dead", "1", "--devices", "2",
              "--arch", "gpt2m", "--reduced", "--seq", "16",
              "--batch", "4", "--docs", "60", "--vocab", "256",
              "--ckpt-every", "2"]

    def run(extra):
        cmd = [sys.executable, "-m", "repro.launch.replan",
               *common, *extra]
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=560, env=subproc_env)
        assert out.returncode == 0, out.stderr[-2000:]
        line = [l for l in out.stdout.splitlines()
                if l.startswith("{")][-1]
        return json.loads(line)

    chaos = run(["--steps", "5", "--kill-step", "3",
                 "--plan", "pipeshard"])
    assert chaos["mode"] == "chaos" and chaos["failed"]
    assert chaos["sites_old"] == [0]
    assert chaos["resumed_from"] == 2            # ckpt_every=2, killed at 3
    assert chaos["steps_lost"] == 1
    assert chaos["final_loss"] is not None

    rec = run(["--steps", "8"])                  # no --kill-step: recovery
    assert rec["mode"] == "recovery"
    assert rec["sites_old"] == [0]
    assert rec["resumed_from"] == 5              # the chaos run's final save
    assert rec["final_loss"] is not None
