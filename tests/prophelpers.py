"""Optional-hypothesis shim for property-based tests.

``hypothesis`` is a test extra (see pyproject.toml ``[test]``).  Test
modules import ``given/settings/st`` from here instead of from
``hypothesis`` directly, so that when the extra is not installed the
property tests collect and *skip* cleanly instead of failing the whole
module at import time (pytest finds this module through the tests
directory on sys.path, same as conftest auto-discovery).
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Replace the property test with a zero-arg skipper (no fixture
        lookup on the strategy parameter names)."""
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed — property test "
                            "(install the [test] extra to run)")
            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """st.<anything>(...) is only evaluated at decoration time; the
        value is never drawn from, so an inert placeholder suffices."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
