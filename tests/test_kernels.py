"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), swept over
shapes and dtypes per the assignment, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from prophelpers import given, settings, st

from repro.kernels import ops, ref


def _mk(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #

FLASH_CASES = [
    # (B, S, H, KV, D, window, dtype)
    (2, 64, 4, 2, 32, 0, jnp.float32),
    (1, 128, 4, 4, 64, 0, jnp.float32),
    (2, 96, 8, 2, 48, 32, jnp.float32),    # GQA + window + padding
    (1, 64, 2, 1, 128, 0, jnp.float32),    # MQA
    (2, 64, 4, 2, 32, 0, jnp.bfloat16),
    (1, 256, 2, 2, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KV,D,window,dtype", FLASH_CASES)
def test_flash_attention_vs_ref(B, S, H, KV, D, window, dtype):
    rng = np.random.default_rng(0)
    q = _mk(rng, (B, S, H, D), dtype)
    k = _mk(rng, (B, S, KV, D), dtype)
    v = _mk(rng, (B, S, KV, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        window=window).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([32, 48, 64]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_property(s, h, d, seed):
    """Property: rows of the attention output are convex combinations of V
    rows => output is bounded by V's min/max per feature (plus eps)."""
    rng = np.random.default_rng(seed)
    q = _mk(rng, (1, s, h, d), jnp.float32)
    k = _mk(rng, (1, s, h, d), jnp.float32)
    v = _mk(rng, (1, s, h, d), jnp.float32)
    out = np.asarray(ops.flash_attention(q, k, v, causal=True, block_q=16,
                                         block_k=16, interpret=True))
    vmin, vmax = np.min(np.asarray(v)), np.max(np.asarray(v))
    assert out.min() >= vmin - 1e-4 and out.max() <= vmax + 1e-4
    # first position attends only to itself
    np.testing.assert_allclose(out[0, 0], np.asarray(v)[0, 0], atol=1e-5)


# ------------------------------------------------------------------ #
# SSD (mamba2) scan
# ------------------------------------------------------------------ #

SSD_CASES = [
    # (B, S, nh, hd, ds, chunk)
    (2, 128, 3, 16, 8, 32),
    (1, 64, 2, 32, 16, 16),
    (2, 96, 1, 8, 4, 32),      # padding (96 % 32 == 0 but odd sizes)
    (1, 80, 4, 16, 8, 32),     # S not multiple of chunk => pad path
]


@pytest.mark.parametrize("B,S,nh,hd,ds,chunk", SSD_CASES)
def test_ssd_scan_vs_ref(B, S, nh, hd, ds, chunk):
    rng = np.random.default_rng(1)
    xh = _mk(rng, (B, S, nh, hd), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    bs = _mk(rng, (B, S, ds), jnp.float32)
    cs = _mk(rng, (B, S, ds), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    y, h = ops.ssd_scan(xh, dt, bs, cs, a, chunk=chunk, interpret=True)
    yr, hr = ref.ssd_ref(xh.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                         bs, cs, a)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(yr.transpose(0, 2, 1, 3)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


def test_ssd_model_path_matches_jnp():
    """models.ssm._ssd_chunk_scan (jnp) vs the kernel, through mamba2."""
    from repro.models.ssm import _ssd_chunk_scan
    rng = np.random.default_rng(2)
    B, S, nh, hd, ds = 2, 64, 2, 16, 8
    xh = _mk(rng, (B, S, nh, hd), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    bs = _mk(rng, (B, S, ds), jnp.float32)
    cs = _mk(rng, (B, S, ds), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    y_jnp, h_jnp = _ssd_chunk_scan(xh, dt, bs, cs, a, h0, chunk=16)
    y_k, h_k = ops.ssd_scan(xh, dt, bs, cs, a, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_k),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_jnp), np.asarray(h_k),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ #
# mamba1 scan
# ------------------------------------------------------------------ #

M1_CASES = [
    (2, 64, 24, 8, 16),
    (1, 128, 16, 4, 32),
    (2, 48, 8, 8, 16),     # S pads to chunk multiple
]


@pytest.mark.parametrize("B,S,di,ds,chunk", M1_CASES)
def test_mamba1_scan_vs_ref(B, S, di, ds, chunk):
    rng = np.random.default_rng(3)
    x = _mk(rng, (B, S, di), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, di)), jnp.float32)
    bs = _mk(rng, (B, S, ds), jnp.float32)
    cs = _mk(rng, (B, S, ds), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (di, ds)), jnp.float32)
    y, h = ops.mamba1_scan(x, dt, bs, cs, A, chunk=chunk, interpret=True)
    yr, hr = ref.mamba1_ref(x, dt, bs, cs, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       s=st.sampled_from([16, 32, 64]))
def test_ssd_state_decay_property(seed, s):
    """Property: with negative A, a zero-input suffix decays the state
    monotonically (|h| after extra zero steps <= before)."""
    rng = np.random.default_rng(seed)
    B, nh, hd, ds = 1, 2, 8, 4
    xh = np.zeros((B, 2 * s, nh, hd), np.float32)
    xh[:, :s] = rng.standard_normal((B, s, nh, hd))
    dt = np.full((B, 2 * s, nh), 0.1, np.float32)
    bs = rng.standard_normal((B, 2 * s, ds)).astype(np.float32)
    cs = rng.standard_normal((B, 2 * s, ds)).astype(np.float32)
    a = -np.abs(rng.standard_normal(nh)).astype(np.float32) - 0.1
    _, h_half = ops.ssd_scan(jnp.asarray(xh[:, :s]), jnp.asarray(dt[:, :s]),
                             jnp.asarray(bs[:, :s]), jnp.asarray(cs[:, :s]),
                             jnp.asarray(a), chunk=16, interpret=True)
    xh2 = xh.copy()
    xh2[:, s:] = 0.0
    _, h_full = ops.ssd_scan(jnp.asarray(xh2), jnp.asarray(dt),
                             jnp.asarray(bs), jnp.asarray(cs),
                             jnp.asarray(a), chunk=16, interpret=True)
    assert float(jnp.max(jnp.abs(h_full))) <= \
        float(jnp.max(jnp.abs(h_half))) + 1e-5


def test_model_level_pallas_parity():
    """use_pallas=True end-to-end forward equals the jnp path."""
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.registry import input_specs
    from repro.configs.base import ShapeConfig
    rng = np.random.default_rng(0)
    shape = ShapeConfig("t", 64, 1, "train")
    for arch in ("llama3.2-3b", "falcon-mamba-7b", "zamba2-2.7b"):
        cfg = get_config(arch).reduced()
        m0, m1 = Model(cfg), Model(cfg, use_pallas=True)
        params = m0.init(jax.random.key(0))
        batch = input_specs(cfg, shape, abstract=False, rng=rng)
        l0, _ = m0.forward(params, batch, remat=False)
        l1, _ = m1.forward(params, batch, remat=False)
        np.testing.assert_allclose(
            np.asarray(l0, np.float32), np.asarray(l1, np.float32),
            atol=2e-2, rtol=2e-2)


# ------------------------------------------------------------------ #
# fused RMSNorm
# ------------------------------------------------------------------ #

RMS_CASES = [
    ((4, 32, 64), jnp.float32, 16),
    ((2, 100, 128), jnp.bfloat16, 32),   # rows pad to block multiple
    ((7, 96), jnp.float32, 4),
]


@pytest.mark.parametrize("shape,dtype,block", RMS_CASES)
def test_rmsnorm_vs_ref(shape, dtype, block):
    rng = np.random.default_rng(4)
    x = _mk(rng, shape, dtype)
    w = _mk(rng, shape[-1:], dtype) + 1.0
    out = ops.rmsnorm(x, w, block_rows=block, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rmsnorm as model_rmsnorm
    rng = np.random.default_rng(5)
    x = _mk(rng, (3, 17, 64), jnp.float32)
    w = _mk(rng, (64,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, w, interpret=True)),
        np.asarray(model_rmsnorm(x, w)), atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 50), d=st.sampled_from([8, 32, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_unit_norm_property(rows, d, seed):
    """Property: with unit weight, output rows have RMS ~= 1."""
    rng = np.random.default_rng(seed)
    x = _mk(rng, (rows, d), jnp.float32) * 5.0
    out = np.asarray(ops.rmsnorm(x, jnp.ones((d,)), block_rows=16,
                                 interpret=True))
    rms = np.sqrt(np.mean(out ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
