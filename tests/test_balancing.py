"""TFLOP-weighted pipeline stage balancing (ROADMAP "heterogeneous stage
balancing"): the layer allocator's invariants, its effect on the cost
model, and the Placement → pipeline_mesh threading."""
import pytest

from prophelpers import given, settings, st

from repro.configs import get_config
from repro.core.costmodel import (balanced_stage_layers, paper_workload,
                                  stage_compute_tflops,
                                  technique_step_cost)
from repro.core.pipeline import pipeline_mesh, validate_stages
from repro.core.plans import Placement
from repro.core.search import PlanSearch
from repro.core.topology import Link, Site, ring

WL_M = paper_workload(get_config("gpt2m"))


def mixed_ring(gpu_types, lat_ms=20.0):
    sites = [Site((g, g), name=f"S{i}") for i, g in enumerate(gpu_types)]
    return ring("mixed", sites, [Link(lat_ms * 1e-3, 3.0)] * len(sites))


# ------------------------------------------------------------------ #
# the allocator
# ------------------------------------------------------------------ #

def test_balanced_split_sums_and_floors():
    split = balanced_stage_layers(24, [50.0, 50.0, 20.0])
    assert split == (10, 10, 4)
    assert sum(split) == 24
    # even a near-zero stage keeps its one mandatory layer
    assert balanced_stage_layers(24, [100.0, 0.001])[1] == 1


def test_balanced_split_homogeneous_is_even():
    assert balanced_stage_layers(24, [25.0] * 3) == (8, 8, 8)
    assert balanced_stage_layers(30, [50.0] * 2) == (15, 15)
    # non-divisible: off-by-one even split, earlier stages first
    assert balanced_stage_layers(30, [25.0] * 4) == (8, 8, 7, 7)


def test_balanced_split_monotone_in_tflops():
    split = balanced_stage_layers(24, [50.0, 20.0, 40.0])
    assert split[0] >= split[2] >= split[1]


def test_balanced_split_validates():
    with pytest.raises(ValueError):
        balanced_stage_layers(2, [1.0, 1.0, 1.0])   # fewer layers than stages
    with pytest.raises(ValueError):
        balanced_stage_layers(8, [1.0, 0.0])        # non-positive tflops
    with pytest.raises(ValueError):
        balanced_stage_layers(8, [])


@settings(max_examples=50, deadline=None)
@given(n_layers=st.integers(4, 96),
       tf=st.lists(st.floats(0.5, 200.0), min_size=1, max_size=6))
def test_balanced_split_properties(n_layers, tf):
    """Sum, floor, and monotonicity hold for any stage-TFLOP/s vector."""
    if n_layers < len(tf):
        n_layers = len(tf)
    split = balanced_stage_layers(n_layers, tf)
    assert sum(split) == n_layers
    assert all(l >= 1 for l in split)
    for i in range(len(tf)):
        for j in range(len(tf)):
            # strict enough that the proportional quotas can't collide
            # to the same float (ties are broken by stage index)
            if tf[i] > tf[j] * (1 + 1e-9):
                assert split[i] >= split[j], (tf, split)


# ------------------------------------------------------------------ #
# cost model: a T4 site gets fewer layers than an A30 site
# ------------------------------------------------------------------ #

def test_t4_site_gets_strictly_fewer_layers_in_mixed_ring():
    topo = mixed_ring(["A30", "A30", "T4"])
    tf = stage_compute_tflops(topo, (0, 1, 2))
    split = balanced_stage_layers(WL_M.cfg.n_layers, tf)
    assert tf == [50.0, 50.0, 20.0]
    assert split[2] < split[0] and split[2] < split[1]


def test_weighted_balance_speeds_up_heterogeneous_pipeshard():
    """On a mixed ring the TFLOP-weighted split strictly beats the even
    split (the T4 stage stops pacing every tick); on a homogeneous ring
    the two are identical."""
    het = mixed_ring(["A30", "A30", "T4"])
    even = technique_step_cost("pipeshard", WL_M, het,
                               stage_balance="even")
    bal = technique_step_cost("pipeshard", WL_M, het,
                              stage_balance="tflops")
    assert bal.compute_s < even.compute_s
    hom = mixed_ring(["A30", "A30", "A30"])
    e = technique_step_cost("pipeshard", WL_M, hom, stage_balance="even")
    b = technique_step_cost("pipeshard", WL_M, hom,
                            stage_balance="tflops")
    assert b.total_s == pytest.approx(e.total_s)


def test_explicit_stage_layers_override_and_validate():
    topo = mixed_ring(["A30", "T4", "A30"])
    c = technique_step_cost("pipeshard", WL_M, topo,
                            stage_layers=[10, 4, 10])
    assert c.compute_s > 0
    with pytest.raises(ValueError, match="partition"):
        technique_step_cost("pipeshard", WL_M, topo,
                            stage_layers=[10, 10, 10])
    with pytest.raises(ValueError, match="stage_balance"):
        technique_step_cost("pipeshard", WL_M, topo,
                            stage_balance="nonsense")


def test_plansearch_placement_attaches_balanced_layers():
    topo = mixed_ring(["A30", "A30", "T4"])
    search = PlanSearch(WL_M, topo, stage_balance="tflops")
    cand = next(c for c in search.candidates()
                if c.technique == "pipeshard" and c.sites == (0, 1, 2))
    p = search.placement(cand)
    assert p.stage_layers == (10, 10, 4)
    # even-balance searches keep the legacy bare placement
    bare = PlanSearch(WL_M, topo).placement(cand)
    assert bare.stage_layers is None


# ------------------------------------------------------------------ #
# Placement / mesh threading
# ------------------------------------------------------------------ #

def test_placement_validates_stage_layers():
    p = Placement(sites=(0, 1, 2), stage_order=(2, 0, 1),
                  stage_layers=(4, 10, 10))
    assert p.n_stages == 3
    with pytest.raises(ValueError, match="entries"):
        Placement(sites=(0, 1), stage_layers=(8, 8, 8))
    with pytest.raises(ValueError, match=">= 1"):
        Placement(sites=(0, 1), stage_layers=(24, 0))


def test_pipeline_mesh_accepts_weighted_splits():
    from repro.launch.mesh import make_host_mesh
    base = make_host_mesh((1, 1), ("data", "model"))
    mesh = pipeline_mesh(base, 1, stage_layers=(24,))
    assert mesh.shape["stage"] == 1
    with pytest.raises(ValueError, match="entries"):
        pipeline_mesh(base, 1, stage_layers=(16, 8))
    with pytest.raises(ValueError, match=">= 1"):
        pipeline_mesh(base, 1, stage_layers=(0,))


def test_validate_stages_accepts_uneven_and_rejects_bad_splits():
    import numpy as np
    cfg = get_config("gpt2m")
    stack = {"w": np.zeros((24, 4))}
    assert validate_stages(cfg, stack, 2, stage_layers=(12, 12)) == (12, 12)
    # uneven splits are realized at runtime now (pad-and-mask)
    assert validate_stages(cfg, stack, 2, stage_layers=(16, 8)) == (16, 8)
    assert validate_stages(cfg, stack, 3, stage_layers=(10, 10, 4)) \
        == (10, 10, 4)
    assert validate_stages(cfg, stack, 2) is None
    with pytest.raises(ValueError, match="partition"):
        validate_stages(cfg, stack, 2, stage_layers=(12, 14))
    with pytest.raises(ValueError, match="partition"):
        validate_stages(cfg, stack, 2, stage_layers=(24, 0))
    # no explicit split: the stack must divide evenly across stages
    with pytest.raises(ValueError, match="divisible"):
        validate_stages(cfg, stack, 5)
