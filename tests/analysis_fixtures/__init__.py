# Negative-test fixtures for repro.analysis (tests/test_analysis.py).
# These files are parsed by the analyzers, never imported or executed;
# no test_ prefix, so pytest does not collect them.
