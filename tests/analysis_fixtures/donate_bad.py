"""The PR-7 ``reshard_check`` bug, reduced: ``train`` donates its state
buffers (device_put may alias when src and dst shardings coincide), and
the parity check then reuses the restored host arrays for the control
run — reading deleted buffers.  donatecheck must flag every marked
line (DON001/DON002); the fixed twin is donate_good.py.
"""
import jax


def build_train_step(model):
    step = jax.jit(model.step, donate_argnums=(0, 1))
    return step, {"params": None, "opt": None}


def train(model, params, opt_state, batch):
    step_fn, sh = build_train_step(model)
    params = jax.device_put(params, sh["params"])      # may alias!
    opt_state = jax.device_put(opt_state, sh["opt"])
    params, opt_state, loss = step_fn(params, opt_state, batch)
    return loss


def run_place(model, ckpt, batch):
    params_h, opt_h = ckpt.restore()
    # resharded run donates the restored arrays ...
    loss_resharded = train(model, params_h, opt_h, batch)
    # ... and the control run reads them again: DON001 x2
    loss_control = train(model, params_h, opt_h, batch)
    return loss_resharded, loss_control


def loop_never_rebinds(model, params, opt_state, batches):
    step_fn, _ = build_train_step(model)
    for batch in batches:
        # DON001: next iteration donates the buffer iteration one freed
        out = step_fn(params, opt_state, batch)
    return out


def donated_and_read_slot(model, params, opt_state, batch):
    step_fn, _ = build_train_step(model)
    # DON002: params is both donated (arg 0) and read (inside arg 2)
    return step_fn(params, opt_state, (batch, params))


def unverifiable_argnums(model, nums):
    # DON003: the donation contract is not a literal
    step = jax.jit(model.step, donate_argnums=nums)
    return step
