"""The fixed twin of donate_bad.py: the parity check snapshots the
restored state with ``host_copy`` (a fresh-copy call) before the
donating run, loops rebind their donated operands, and no argument
slot is both donated and read.  donatecheck must report nothing here.
"""
import copy

import jax
import numpy as np


def host_copy(tree):
    return jax.tree.map(np.asarray, tree)


def build_train_step(model):
    step = jax.jit(model.step, donate_argnums=(0, 1))
    return step, {"params": None, "opt": None}


def train(model, params, opt_state, batch):
    step_fn, sh = build_train_step(model)
    params = jax.device_put(params, sh["params"])
    opt_state = jax.device_put(opt_state, sh["opt"])
    params, opt_state, loss = step_fn(params, opt_state, batch)
    return loss


def run_place(model, ckpt, batch):
    params_h, opt_h = ckpt.restore()
    params_ctl = host_copy(params_h)
    opt_ctl = copy.deepcopy(opt_h)
    loss_resharded = train(model, params_h, opt_h, batch)
    loss_control = train(model, params_ctl, opt_ctl, batch)
    return loss_resharded, loss_control


def loop_rebinds(model, params, opt_state, batches):
    step_fn, _ = build_train_step(model)
    for batch in batches:
        params, opt_state, loss = step_fn(params, opt_state, batch)
    return params, opt_state, loss
