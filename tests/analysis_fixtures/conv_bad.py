"""Seeded convention violations for tests/test_analysis.py: a
unit-mixing arithmetic expression (CONV001) and broad exception
handlers that swallow (CONV002).  The clean shapes sit alongside so
the tests also prove the rules do not overfire.
"""


def mixed_units(compute_s, bytes_wire, link_gbps, overhead_ms):
    # CONV001: seconds + bytes
    bad_total = compute_s + bytes_wire
    # CONV001: milliseconds - gigabits per second
    bad_delta = overhead_ms - link_gbps
    # fine: same unit, and unitless scaling
    ok_total = compute_s + overhead_ms / 1e3
    ok_scaled = 2.0 * compute_s
    return bad_total, bad_delta, ok_total, ok_scaled


def swallow_and_return_none(path):
    try:
        return open(path).read()
    except Exception:
        return None  # CONV002: broad except that hides every failure


def swallow_and_pass(path):
    try:
        return open(path).read()
    except Exception:  # CONV002
        pass


def narrow_is_fine(path):
    try:
        return open(path).read()
    except FileNotFoundError:
        return None


def broad_but_reraises(path):
    try:
        return open(path).read()
    except Exception as exc:
        raise RuntimeError(path) from exc
