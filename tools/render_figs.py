"""Render ``benchmarks/out/*.json`` to standalone SVG figures — no
plotting dependency, stdlib string-built SVG only (ROADMAP "winner-map
visualization").

    PYTHONPATH=src python tools/render_figs.py \\
        [--src benchmarks/out] [--out docs/figs] [--mode full]

Renders, per matching artifact:

  * ``pipeline_schedules_<mode>.json`` → ``schedule_steptime_*.svg`` +
    ``schedule_memory_*.svg`` (the GPipe/1F1B/interleaved ablation,
    docs/schedules.md);
  * ``latency_sweep_<kind><n>_<mode>.json`` → Fig. 5-style degradation
    curves with the winner flips marked;
  * ``topology_sweep_<mode>.json`` → winner maps — one colored cell per
    (topology × GPU mix), one panel per latency regime, one figure per
    model; ``topology_sweep_all_<mode>.json`` (the ``--techniques all``
    pool) additionally tags cells a beyond-paper technique wins
    (SZ = shard_zero, FS = fsdp — docs/cost-model.md);
    ``topology_sweep_wire_<mode>.json`` (the ``--wire`` pool) tags cells
    a quantized wire wins (I8 = int8, B16 = bf16 —
    docs/quantization.md).

Colors are a fixed per-entity assignment from a validated
colorblind-safe categorical palette (techniques and schedules each keep
their hue across every figure; never cycled).  Exits non-zero when no
inputs are found, so CI fails loudly on an empty ``benchmarks/out/``.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

# Validated categorical palette (light mode) — fixed assignment per
# entity, never cycled; gray is the OOM/none slot.
SERIES = {"blue": "#2a78d6", "orange": "#eb6834", "aqua": "#1baf7a",
          "yellow": "#eda100", "magenta": "#e87ba4", "green": "#008300"}
TECH_COLOR = {"data": SERIES["blue"], "pipeshard": SERIES["orange"],
              "zero2": SERIES["yellow"], "shard": SERIES["aqua"],
              "shard_zero": SERIES["magenta"], "fsdp": SERIES["green"]}
SCHED_COLOR = {"gpipe": SERIES["blue"], "1f1b": SERIES["orange"],
               "interleaved": SERIES["aqua"]}
OOM = "#b5b4ac"
SURFACE, INK, INK2, GRID = "#fcfcfb", "#0b0b0b", "#52514e", "#e5e4e0"
FONT = ("font-family='system-ui,-apple-system,Segoe UI,Helvetica,Arial,"
        "sans-serif'")


def _esc(s: str) -> str:
    return (str(s).replace("&", "&amp;").replace("<", "&lt;")
            .replace(">", "&gt;").replace('"', "&quot;"))


def _svg(w: int, h: int, body: List[str]) -> str:
    return "\n".join(
        [f"<svg xmlns='http://www.w3.org/2000/svg' width='{w}' "
         f"height='{h}' viewBox='0 0 {w} {h}' role='img'>",
         f"<rect width='{w}' height='{h}' fill='{SURFACE}'/>"]
        + body + ["</svg>"]) + "\n"


def _text(x, y, s, *, size=12, color=INK, anchor="start",
          weight="normal") -> str:
    return (f"<text x='{x:.1f}' y='{y:.1f}' {FONT} font-size='{size}' "
            f"fill='{color}' text-anchor='{anchor}' "
            f"font-weight='{weight}'>{_esc(s)}</text>")


def _nice_ticks(lo: float, hi: float, n: int = 5) -> List[float]:
    if hi <= lo:
        hi = lo + 1.0
    raw = (hi - lo) / max(n - 1, 1)
    mag = 10 ** math.floor(math.log10(raw))
    step = min(s * mag for s in (1, 2, 2.5, 5, 10) if s * mag >= raw)
    t0 = math.floor(lo / step) * step
    ticks = []
    t = t0
    while t <= hi + 1e-9:
        if t >= lo - 1e-9:
            ticks.append(round(t, 10))
        t += step
    return ticks or [lo, hi]


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e6:
        return str(int(v))
    return f"{v:g}"


class _Axes:
    """A tiny x/y plot frame: scales, grid, ticks, labels."""

    def __init__(self, w, h, *, ml=56, mr=16, mt=34, mb=42,
                 logx=False):
        self.w, self.h = w, h
        self.ml, self.mr, self.mt, self.mb = ml, mr, mt, mb
        self.logx = logx

    def fit(self, xs: Sequence[float], ys: Sequence[float],
            y0: Optional[float] = 0.0):
        tx = [math.log10(x) for x in xs] if self.logx else list(xs)
        self.x_lo, self.x_hi = min(tx), max(tx)
        if self.x_hi == self.x_lo:
            self.x_hi += 1.0
        ys = list(ys)
        if y0 is not None:
            ys.append(y0)
        self.y_ticks = _nice_ticks(min(ys), max(ys))
        self.y_lo, self.y_hi = self.y_ticks[0], self.y_ticks[-1]

    def X(self, x: float) -> float:
        tx = math.log10(x) if self.logx else x
        f = (tx - self.x_lo) / (self.x_hi - self.x_lo)
        return self.ml + f * (self.w - self.ml - self.mr)

    def Y(self, y: float) -> float:
        f = (y - self.y_lo) / (self.y_hi - self.y_lo)
        return self.h - self.mb - f * (self.h - self.mt - self.mb)

    def frame(self, title, xlabel, ylabel,
              x_ticks: Sequence[float]) -> List[str]:
        b = [_text(self.ml, 20, title, size=13, weight="600")]
        for yt in self.y_ticks:
            y = self.Y(yt)
            b.append(f"<line x1='{self.ml}' y1='{y:.1f}' "
                     f"x2='{self.w - self.mr}' y2='{y:.1f}' "
                     f"stroke='{GRID}' stroke-width='1'/>")
            b.append(_text(self.ml - 6, y + 4, _fmt(yt), size=11,
                           color=INK2, anchor="end"))
        for xt in x_ticks:
            x = self.X(xt)
            b.append(_text(x, self.h - self.mb + 16, _fmt(xt), size=11,
                           color=INK2, anchor="middle"))
        b.append(f"<line x1='{self.ml}' y1='{self.h - self.mb}' "
                 f"x2='{self.w - self.mr}' y2='{self.h - self.mb}' "
                 f"stroke='{INK2}' stroke-width='1'/>")
        b.append(_text((self.ml + self.w - self.mr) / 2,
                       self.h - 8, xlabel, size=11, color=INK2,
                       anchor="middle"))
        b.append(f"<text x='14' y='{(self.mt + self.h - self.mb) / 2:.1f}'"
                 f" {FONT} font-size='11' fill='{INK2}' "
                 f"text-anchor='middle' transform='rotate(-90 14 "
                 f"{(self.mt + self.h - self.mb) / 2:.1f})'>"
                 f"{_esc(ylabel)}</text>")
        return b

    def polyline(self, pts: Sequence[Tuple[float, float]], color: str,
                 *, dash: str = "") -> List[str]:
        """2px line + 8px markers; None-y gaps split the line."""
        out = []
        seg: List[str] = []
        d = f" stroke-dasharray='{dash}'" if dash else ""
        for x, y in pts:
            if y is None:
                if len(seg) > 1:
                    out.append(f"<polyline points='{' '.join(seg)}' "
                               f"fill='none' stroke='{color}' "
                               f"stroke-width='2'{d}/>")
                seg = []
                continue
            seg.append(f"{self.X(x):.1f},{self.Y(y):.1f}")
        if len(seg) > 1:
            out.append(f"<polyline points='{' '.join(seg)}' fill='none' "
                       f"stroke='{color}' stroke-width='2'{d}/>")
        for x, y in pts:
            if y is not None:
                out.append(
                    f"<circle cx='{self.X(x):.1f}' cy='{self.Y(y):.1f}' "
                    f"r='4' fill='{color}' stroke='{SURFACE}' "
                    f"stroke-width='2'><title>{_esc(f'{x:g}: {y:g}')}"
                    f"</title></circle>")
        return out


def _legend(x, y, entries: Sequence[Tuple[str, str]],
            dx: int = 110) -> List[str]:
    out = []
    for i, (label, color) in enumerate(entries):
        cx = x + i * dx
        out.append(f"<rect x='{cx}' y='{y - 9}' width='14' height='4' "
                   f"rx='2' fill='{color}'/>")
        out.append(_text(cx + 20, y, label, size=11, color=INK2))
    return out


# --------------------------------------------------------------------- #
# figure builders
# --------------------------------------------------------------------- #

def fig_schedule_curves(record: dict, scenario: str, field: str,
                        title: str, ylabel: str) -> str:
    rows = record["scenarios"][scenario]["rows"]
    ms = sorted({r["n_micro"] for r in rows})
    scheds = [s for s in SCHED_COLOR if any(r["schedule"] == s
                                            for r in rows)]
    ax = _Axes(620, 340, logx=True)
    ys = [r[field] for r in rows if r[field] is not None]
    avail = rows[0]["mem_avail_gb"] if field == "mem_gb" else None
    ax.fit(ms, ys + ([avail] if avail else []),
           y0=0.0 if field != "mem_gb" else None)
    body = ax.frame(title, "microbatches m (log)", ylabel, ms)
    if avail:
        y = ax.Y(avail)
        body.append(f"<line x1='{ax.ml}' y1='{y:.1f}' "
                    f"x2='{ax.w - ax.mr}' y2='{y:.1f}' stroke='{INK2}' "
                    f"stroke-width='1' stroke-dasharray='6 4'/>")
        body.append(_text(ax.w - ax.mr, y - 6, "GPU memory", size=10,
                          color=INK2, anchor="end"))
    for s in scheds:
        pts = [(m, next(r[field] for r in rows
                        if r["n_micro"] == m and r["schedule"] == s))
               for m in ms]
        body += ax.polyline(pts, SCHED_COLOR[s])
        lab = [(x, y) for x, y in pts if y is not None
               and ax.X(x) < ax.w - 96]
        if lab:
            x, y = lab[-1]
            body.append(_text(ax.X(x) + 8, ax.Y(y) - 8, s, size=11,
                              color=INK2))
    body += _legend(ax.ml, ax.h - ax.mb + 34,
                    [(s, SCHED_COLOR[s]) for s in scheds])
    return _svg(ax.w, ax.h + 10, body)


def fig_latency_sweep(record: dict) -> str:
    rows = record["points"]
    series = [("pipeshard@all", "pipeshard_all", TECH_COLOR["pipeshard"],
               ""),
              ("data@all", "data_all", TECH_COLOR["data"], ""),
              ("best data pair", "data_best_pair", TECH_COLOR["data"],
               "5 4"),
              ("best single site", "best_single_site",
               TECH_COLOR["zero2"], "5 4")]
    lats = [r["latency_ms"] for r in rows]
    ys = [r[k] for _, k, _, _ in series for r in rows
          if r[k] is not None]
    ax = _Axes(640, 360, logx=True)
    ax.fit(lats, ys)
    kind, n = record["kind"], record["n"]
    body = ax.frame(
        f"Latency sweep — {kind}{n} / {record['mix']} / "
        f"{record['model']} (swept "
        f"{'middle' if kind == 'line' else 'closing'} edge)",
        "swept edge RTT ms (log)", "TFLOP/s",
        [l for l in (0.1, 1, 10, 100) if min(lats) <= l <= max(lats)])
    for label, key, color, dash in series:
        pts = [(r["latency_ms"], r[key]) for r in rows]
        body += ax.polyline(pts, color, dash=dash)
    for f in record.get("flips", []):
        lo, hi = f["between_ms"]
        x = ax.X(math.sqrt(lo * hi))
        tip = _esc(f"{f['from']} → {f['to']}")
        body.append(f"<line x1='{x:.1f}' y1='{ax.mt}' x2='{x:.1f}' "
                    f"y2='{ax.h - ax.mb}' stroke='{INK2}' "
                    f"stroke-width='1' stroke-dasharray='2 4'>"
                    f"<title>{tip}</title></line>")
    body += _legend(ax.ml, ax.h - ax.mb + 34,
                    [(lbl, c) for lbl, _, c, _ in series], dx=130)
    return _svg(ax.w, ax.h + 10, body)


def fig_winner_map(record: dict, model: str) -> str:
    entries = [e for e in record["entries"] if e["model"] == model]
    regimes = sorted({e["regime"] for e in entries},
                     key=lambda r: next(x["latency_ms"] for x in entries
                                        if x["regime"] == r))
    mixes = sorted({e["mix"] for e in entries})
    topos = sorted({(e["kind"], e["n"]) for e in entries})
    cell, row_h = 46, 22
    label_w, panel_gap, top = 72, 24, 56
    panel_w = label_w + len(mixes) * cell
    w = 16 + len(regimes) * (panel_w + panel_gap)
    h = top + len(topos) * row_h + 60
    pool = ", all techniques" if record.get("techniques") == "all" else ""
    if record.get("wire"):
        pool += ", fp32/bf16/int8 wire"
    body = [_text(16, 22, f"Winner map — {model} "
                  f"(balance={record['balance']}{pool})", size=13,
                  weight="600")]
    by = {(e["regime"], e["kind"], e["n"], e["mix"]): e for e in entries}
    for pi, regime in enumerate(regimes):
        x0 = 16 + pi * (panel_w + panel_gap)
        lat = next(e["latency_ms"] for e in entries
                   if e["regime"] == regime)
        body.append(_text(x0 + label_w, 44,
                          f"{regime} ({lat:g} ms)", size=11,
                          weight="600", color=INK2))
        for ci, mix in enumerate(mixes):
            body.append(_text(x0 + label_w + ci * cell + cell / 2,
                              top - 2, mix, size=9, color=INK2,
                              anchor="middle"))
        for ri, (kind, n) in enumerate(topos):
            y = top + ri * row_h
            body.append(_text(x0 + label_w - 6, y + 15,
                              f"{kind}{n}", size=10, color=INK2,
                              anchor="end"))
            for ci, mix in enumerate(mixes):
                e = by.get((regime, kind, n, mix))
                win = (e or {}).get("winner")
                color = OOM if win is None else \
                    TECH_COLOR.get(win["technique"], OOM)
                tip = "no data" if e is None else (
                    "OOM" if win is None else
                    f"{win['key']} — {win['tflops']:g} TFLOP/s")
                body.append(
                    f"<rect x='{x0 + label_w + ci * cell + 1}' "
                    f"y='{y + 1}' width='{cell - 2}' "
                    f"height='{row_h - 2}' rx='3' fill='{color}'>"
                    f"<title>{_esc(tip)}</title></rect>")
                tag = None
                if win and win.get("wire_dtype", "fp32") != "fp32":
                    # quantized wire took the cell (mirrors the sweep's
                    # ~int8/~bf16 markdown tag, docs/quantization.md)
                    tag = {"int8": "I8", "bf16": "B16"}.get(
                        win["wire_dtype"], win["wire_dtype"][:2].upper())
                elif win and win.get("schedule", "gpipe") != "gpipe":
                    tag = {"1f1b": "1F", "interleaved": "IL"}.get(
                        win["schedule"], win["schedule"][:2])
                elif win and win.get("extended"):
                    # beyond-paper technique took the cell (mirrors the
                    # sweep's † markdown tag, docs/cost-model.md)
                    tag = {"shard_zero": "SZ", "fsdp": "FS"}.get(
                        win["technique"], win["technique"][:2].upper())
                if tag:
                    body.append(_text(
                        x0 + label_w + ci * cell + cell / 2, y + 15,
                        tag, size=9, color=SURFACE, anchor="middle",
                        weight="600"))
    techs = sorted({(e["winner"] or {}).get("technique") for e in entries
                    if e["winner"]})
    leg = [(t, TECH_COLOR.get(t, OOM)) for t in techs] + [("OOM", OOM)]
    body += _legend(16, h - 28, leg, dx=96)
    note = ("1F / IL cell tags: the winning pipeline schedule is 1F1B / "
            "interleaved (docs/schedules.md)")
    if record.get("techniques") == "all":
        note += "; SZ / FS: a beyond-paper technique won the cell"
    if record.get("wire"):
        note += "; I8 / B16: a quantized wire won the cell"
    body.append(_text(16, h - 10, note, size=10, color=INK2))
    return _svg(w, h, body)


# --------------------------------------------------------------------- #

def render_all(src: str, out: str, mode: str = "full",
               print_fn=print) -> List[str]:
    """Render every recognized artifact of ``mode``; returns the list of
    SVG paths written."""
    os.makedirs(out, exist_ok=True)
    written = []

    def emit(name: str, svg: str):
        path = os.path.join(out, name)
        with open(path, "w") as f:
            f.write(svg)
        written.append(path)
        print_fn(f"wrote {path}")

    p = os.path.join(src, f"pipeline_schedules_{mode}.json")
    if os.path.exists(p):
        rec = json.load(open(p))
        emit(f"schedule_steptime_{mode}.svg", fig_schedule_curves(
            rec, "bubble", "step_s",
            "Schedule ablation — step time vs microbatches "
            "(gpt2m, 3-site A30 metro line)", "step seconds"))
        emit(f"schedule_memory_{mode}.svg", fig_schedule_curves(
            rec, "memory", "mem_gb",
            "Schedule ablation — activation stash vs microbatches "
            "(gpt2L b52, 3-site RTX line)", "memory GB/GPU"))
    for p in sorted(glob.glob(
            os.path.join(src, f"latency_sweep_*_{mode}.json"))):
        rec = json.load(open(p))
        emit(f"latency_{rec['kind']}{rec['n']}_{mode}.svg",
             fig_latency_sweep(rec))
    for stem, suffix in ((f"topology_sweep_{mode}", ""),
                         (f"topology_sweep_all_{mode}", "_all"),
                         (f"topology_sweep_wire_{mode}", "_wire")):
        p = os.path.join(src, f"{stem}.json")
        if os.path.exists(p):
            rec = json.load(open(p))
            for model in sorted({e["model"] for e in rec["entries"]}):
                emit(f"winners_{model}{suffix}_{mode}.svg",
                     fig_winner_map(rec, model))
    return written


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--src", default=os.path.join("benchmarks", "out"))
    ap.add_argument("--out", default=os.path.join("docs", "figs"))
    ap.add_argument("--mode", default="full", choices=("full", "smoke"),
                    help="which artifact generation to render")
    args = ap.parse_args(argv)
    written = render_all(args.src, args.out, args.mode)
    if not written:
        print(f"render_figs: no {args.mode} artifacts under {args.src} "
              f"— run the benchmarks first "
              f"(benchmarks/topology_sweep.py, latency_sweep.py, "
              f"pipeline_ablation.py --schedules)", file=sys.stderr)
        return 1
    print(f"render_figs: {len(written)} figures -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
