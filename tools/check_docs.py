"""Docs link + symbol checker (CI: docs-and-benchmarks job; also run as
a tier-1 test via tests/test_docs.py).

Checks, over README.md, DESIGN.md, and docs/*.md:

  * every relative markdown link resolves to an existing file, and its
    ``#anchor`` (if any) matches a heading in the target;
  * every backticked dotted ``repro.*`` reference imports/resolves to a
    real module or attribute — so the docs can't name symbols the
    package doesn't have;
  * every backticked repo path (``src/...``, ``benchmarks/...``,
    ``examples/...``, ``tools/...``, ``docs/...``) exists.

Usage: ``python tools/check_docs.py [repo_root]`` — exits non-zero with
one line per problem.
"""
from __future__ import annotations

import importlib
import os
import re
import sys
from typing import List

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`]+)`")
DOTTED_RE = re.compile(r"^(repro(?:\.\w+)+)")
PATH_RE = re.compile(r"^(?:src|benchmarks|examples|tools|docs|tests)/"
                     r"[\w./-]+$")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def doc_files(root: str) -> List[str]:
    files = [os.path.join(root, "README.md"), os.path.join(root, "DESIGN.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return [f for f in files if os.path.isfile(f)]


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, spaces to hyphens, punctuation
    (except hyphens) dropped."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: str) -> set:
    with open(md_path) as f:
        text = f.read()
    return {github_slug(m) for m in HEADING_RE.findall(text)}


def check_links(md_path: str, root: str) -> List[str]:
    errors = []
    with open(md_path) as f:
        text = f.read()
    base = os.path.dirname(md_path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        full = os.path.normpath(os.path.join(base, path)) if path \
            else md_path
        rel = os.path.relpath(md_path, root)
        if not os.path.exists(full):
            errors.append(f"{rel}: broken link -> {target}")
            continue
        if anchor and full.endswith(".md") \
                and anchor not in anchors_of(full):
            errors.append(f"{rel}: missing anchor -> {target}")
    return errors


def resolve_dotted(dotted: str) -> bool:
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_symbols(md_path: str, root: str) -> List[str]:
    errors = []
    with open(md_path) as f:
        text = f.read()
    rel = os.path.relpath(md_path, root)
    for span in CODE_RE.findall(text):
        span = span.strip()
        m = DOTTED_RE.match(span)
        if m and not resolve_dotted(m.group(1)):
            errors.append(f"{rel}: unresolvable symbol `{m.group(1)}`")
        elif PATH_RE.match(span) and "*" not in span \
                and not os.path.exists(os.path.join(root, span)):
            errors.append(f"{rel}: missing path `{span}`")
    return errors


def check_all(root: str) -> List[str]:
    sys.path.insert(0, os.path.join(root, "src"))
    errors = []
    for md in doc_files(root):
        errors += check_links(md, root)
        errors += check_symbols(md, root)
    return errors


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    root = os.path.abspath(args[0]) if args else \
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    errors = check_all(root)
    for e in errors:
        print(e)
    n = len(doc_files(root))
    print(f"check_docs: {n} files, {len(errors)} problems")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
