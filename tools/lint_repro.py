#!/usr/bin/env python
"""Shim for ``python -m repro.analysis`` runnable from the repo root
without setting PYTHONPATH:

    python tools/lint_repro.py [--format json] [--passes ...]

See docs/static-analysis.md for the pass catalog and baseline workflow.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
